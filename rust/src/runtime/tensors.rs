//! Conversion of a [`QuantModel`] into the padded tensor form the AOT
//! artifact expects (the DESIGN.md §2 padding contract):
//!
//! * keys: the model's sorted unique `(feat, thresh)` comparisons, padded
//!   with `(feat 0, thresh i32::MAX)` — a key that never fires;
//! * trees: every tree completed to *perfect* depth-`D` heap form (early
//!   leaves replicated downward); padded trees are all-zero leaves;
//! * biases: `qb_g` as i32.
//!
//! All padding is additive-identity: padded execution is bit-identical to
//! the unpadded integer predictor (property-tested in rust/tests/).

use super::artifact::ArtifactConfig;
use crate::quantize::{QuantModel, QuantNode, QuantTree};
use anyhow::{Context, Result};

/// Padded model tensors ready for literal upload.
#[derive(Clone, Debug)]
pub struct ModelTensors {
    pub cfg: ArtifactConfig,
    /// `[K]` feature index per key.
    pub key_feat: Vec<i32>,
    /// `[K]` threshold per key (padded: i32::MAX).
    pub key_thresh: Vec<i32>,
    /// `[T, 2^D−1]` row-major key index per internal node.
    pub node_key: Vec<i32>,
    /// `[T, 2^D]` row-major leaf values.
    pub leaves: Vec<i32>,
    /// `[NG]` quantized biases.
    pub bias: Vec<i32>,
}

impl ModelTensors {
    /// Build padded tensors for `model` targeting artifact `cfg`.
    ///
    /// Errors if the model does not fit the artifact (too many keys/trees,
    /// too deep, wrong feature count or group count).
    pub fn from_quant(model: &QuantModel, cfg: &ArtifactConfig) -> Result<ModelTensors> {
        anyhow::ensure!(
            model.n_features == cfg.features,
            "model has {} features, artifact {} expects {}",
            model.n_features,
            cfg.name,
            cfg.features
        );
        anyhow::ensure!(
            model.n_groups == cfg.groups,
            "model has {} groups, artifact {} expects {}",
            model.n_groups,
            cfg.name,
            cfg.groups
        );
        anyhow::ensure!(
            model.trees.len() <= cfg.trees,
            "model has {} trees, artifact {} holds {}",
            model.trees.len(),
            cfg.name,
            cfg.trees
        );
        // Round-major tree layout must stay aligned with group = t % NG, so
        // the model's round count must not exceed the padded round count and
        // trees are placed at their original round-major index.
        anyhow::ensure!(
            model.trees.len() % model.n_groups == 0,
            "model tree count not a multiple of groups"
        );

        let comparisons = model.unique_comparisons();
        anyhow::ensure!(
            comparisons.len() <= cfg.keys,
            "model uses {} unique keys, artifact {} holds {}",
            comparisons.len(),
            cfg.name,
            cfg.keys
        );
        let mut key_feat = vec![0i32; cfg.keys];
        let mut key_thresh = vec![i32::MAX; cfg.keys];
        for (i, &(f, t)) in comparisons.iter().enumerate() {
            key_feat[i] = f as i32;
            key_thresh[i] = t as i32;
        }
        let key_index = |f: u32, t: u32| -> Result<i32> {
            comparisons
                .binary_search(&(f, t))
                .map(|i| i as i32)
                .map_err(|_| anyhow::anyhow!("comparison ({f},{t}) missing from key table"))
        };

        let nodes = cfg.nodes();
        let n_leaves = cfg.leaves();
        let mut node_key = vec![0i32; cfg.trees * nodes];
        let mut leaves = vec![0i32; cfg.trees * n_leaves];
        for (ti, tree) in model.trees.iter().enumerate() {
            let nk = &mut node_key[ti * nodes..(ti + 1) * nodes];
            let lv = &mut leaves[ti * n_leaves..(ti + 1) * n_leaves];
            fill_perfect(tree, 0, 0, 0, cfg.depth, nk, lv, &key_index)
                .with_context(|| format!("tree {ti} does not fit depth {}", cfg.depth))?;
        }

        let bias: Vec<i32> = model
            .biases
            .iter()
            .map(|&b| i32::try_from(b).context("bias exceeds i32"))
            .collect::<Result<_>>()?;

        Ok(ModelTensors { cfg: cfg.clone(), key_feat, key_thresh, node_key, leaves, bias })
    }

    /// Convert to XLA literals in artifact argument order
    /// (key_feat, key_thresh, node_key, leaves, bias) — `x` comes first at
    /// execute time.
    pub fn to_literals(&self) -> Result<Vec<xla::Literal>> {
        let cfg = &self.cfg;
        Ok(vec![
            xla::Literal::vec1(&self.key_feat),
            xla::Literal::vec1(&self.key_thresh),
            xla::Literal::vec1(&self.node_key)
                .reshape(&[cfg.trees as i64, cfg.nodes() as i64])?,
            xla::Literal::vec1(&self.leaves)
                .reshape(&[cfg.trees as i64, cfg.leaves() as i64])?,
            xla::Literal::vec1(&self.bias),
        ])
    }
}

/// Recursively fill perfect-tree tables from an arbitrary (≤ depth) tree.
///
/// `tnode` = current source node, `heap` = current heap position at `d`;
/// early leaves replicate downward (key 0, both children the same), which
/// is semantics-preserving because both paths reach the same leaf value.
#[allow(clippy::too_many_arguments)]
fn fill_perfect(
    tree: &QuantTree,
    tnode: usize,
    heap: usize,
    d: usize,
    depth: usize,
    nk: &mut [i32],
    lv: &mut [i32],
    key_index: &dyn Fn(u32, u32) -> Result<i32>,
) -> Result<()> {
    if d == depth {
        // Must be a leaf by now.
        match &tree.nodes[tnode] {
            QuantNode::Leaf { value } => {
                lv[heap - ((1 << depth) - 1)] = *value as i32;
                Ok(())
            }
            QuantNode::Split { .. } => anyhow::bail!("tree deeper than {depth}"),
        }
    } else {
        match &tree.nodes[tnode] {
            QuantNode::Split { feat, thresh, left, right } => {
                nk[heap] = key_index(*feat, *thresh)?;
                fill_perfect(tree, *left as usize, 2 * heap + 1, d + 1, depth, nk, lv, key_index)?;
                fill_perfect(tree, *right as usize, 2 * heap + 2, d + 1, depth, nk, lv, key_index)
            }
            QuantNode::Leaf { .. } => {
                nk[heap] = 0;
                fill_perfect(tree, tnode, 2 * heap + 1, d + 1, depth, nk, lv, key_index)?;
                fill_perfect(tree, tnode, 2 * heap + 2, d + 1, depth, nk, lv, key_index)
            }
        }
    }
}

/// Evaluate the perfect-form tables directly (used by property tests to
/// check `fill_perfect` against [`QuantTree::predict`], and by the
/// coordinator's CPU fallback path).
pub fn eval_perfect(
    node_key: &[i32],
    leaves: &[i32],
    keys: &[u8],
    depth: usize,
) -> i32 {
    let mut idx = 0usize;
    for _ in 0..depth {
        let k = keys[node_key[idx] as usize] as usize;
        idx = 2 * idx + 1 + k;
    }
    leaves[idx - ((1 << depth) - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quantize::QuantNode as N;

    fn cfg(keys: usize, trees: usize, depth: usize, groups: usize) -> ArtifactConfig {
        ArtifactConfig {
            name: "test".into(),
            batch: 4,
            features: 4,
            keys,
            trees,
            depth,
            groups,
        }
    }

    fn shallow_tree() -> QuantTree {
        // depth 1: x0 >= 2 ? 5 : 0
        QuantTree {
            nodes: vec![
                N::Split { feat: 0, thresh: 2, left: 1, right: 2 },
                N::Leaf { value: 0 },
                N::Leaf { value: 5 },
            ],
        }
    }

    fn model_with(trees: Vec<QuantTree>, groups: usize, biases: Vec<i64>) -> QuantModel {
        QuantModel {
            trees,
            n_groups: groups,
            biases,
            n_features: 4,
            w_feature: 4,
            w_tree: 3,
            scale: 1.0,
        }
    }

    #[test]
    fn shallow_tree_replicates_leaves() {
        let m = model_with(vec![shallow_tree()], 1, vec![-3]);
        let t = ModelTensors::from_quant(&m, &cfg(8, 4, 3, 1)).unwrap();
        // Padded to depth 3: walking with key=0 everywhere gives leaf 0,
        // key=1 at root gives 5 regardless of deeper keys.
        let keys_all0 = vec![0u8; 8];
        let mut keys_k0 = vec![0u8; 8];
        // key index of (0,2) is 0 (only comparison).
        keys_k0[0] = 1;
        assert_eq!(eval_perfect(&t.node_key[..7], &t.leaves[..8], &keys_all0, 3), 0);
        assert_eq!(eval_perfect(&t.node_key[..7], &t.leaves[..8], &keys_k0, 3), 5);
    }

    #[test]
    fn padded_trees_are_zero() {
        let m = model_with(vec![shallow_tree()], 1, vec![0]);
        let t = ModelTensors::from_quant(&m, &cfg(8, 4, 2, 1)).unwrap();
        assert!(t.leaves[4..].iter().all(|&v| v == 0));
        assert!(t.node_key[3..].iter().all(|&v| v == 0));
    }

    #[test]
    fn padded_keys_never_fire() {
        let m = model_with(vec![shallow_tree()], 1, vec![0]);
        let t = ModelTensors::from_quant(&m, &cfg(8, 1, 1, 1)).unwrap();
        assert_eq!(t.key_thresh[0], 2);
        assert!(t.key_thresh[1..].iter().all(|&v| v == i32::MAX));
    }

    #[test]
    fn too_deep_rejected() {
        let deep = QuantTree {
            nodes: vec![
                N::Split { feat: 0, thresh: 1, left: 1, right: 2 },
                N::Split { feat: 1, thresh: 1, left: 3, right: 4 },
                N::Leaf { value: 0 },
                N::Leaf { value: 1 },
                N::Leaf { value: 2 },
            ],
        };
        let m = model_with(vec![deep], 1, vec![0]);
        assert!(ModelTensors::from_quant(&m, &cfg(8, 1, 1, 1)).is_err());
    }

    #[test]
    fn too_many_keys_rejected() {
        let t1 = QuantTree {
            nodes: vec![
                N::Split { feat: 0, thresh: 1, left: 1, right: 2 },
                N::Leaf { value: 0 },
                N::Leaf { value: 1 },
            ],
        };
        let t2 = QuantTree {
            nodes: vec![
                N::Split { feat: 1, thresh: 2, left: 1, right: 2 },
                N::Leaf { value: 0 },
                N::Leaf { value: 1 },
            ],
        };
        let m = model_with(vec![t1, t2], 1, vec![0]);
        assert!(ModelTensors::from_quant(&m, &cfg(1, 4, 2, 1)).is_err());
    }

    #[test]
    fn group_mismatch_rejected() {
        let m = model_with(vec![shallow_tree()], 1, vec![0]);
        assert!(ModelTensors::from_quant(&m, &cfg(8, 4, 2, 2)).is_err());
    }
}
