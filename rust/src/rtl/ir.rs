//! Architecture-level IR of a TreeLUT design (paper Figs. 3-6).

/// One root-to-leaf path: a conjunction of key literals
/// (`(key_index, positive)`; positive = key must be 1 = "went right").
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Path {
    pub lits: Vec<(u32, bool)>,
}

impl Path {
    /// True when the path has no conditions (single-leaf tree).
    pub fn is_unconditional(&self) -> bool {
        self.lits.is_empty()
    }
}

/// The boolean-level structure of one quantized tree (paper Fig. 6):
/// for every *unique non-zero* leaf value, the set of paths selecting it.
/// A value's selector is the OR of its path ANDs; output bit `j` is the OR
/// of selectors of values with bit `j` set.
#[derive(Clone, Debug, Default)]
pub struct TreeLogic {
    /// `(leaf value, paths)` sorted by value; value 0 omitted (contributes
    /// nothing to the adder — the quantizer guarantees min leaf = 0).
    pub cases: Vec<(u32, Vec<Path>)>,
    /// Output bitwidth (bits of the max leaf; §2.2.2 footnote 5).
    pub out_bits: u32,
}

impl TreeLogic {
    /// Max leaf value this logic can emit.
    pub fn max_value(&self) -> u32 {
        self.cases.last().map(|(v, _)| *v).unwrap_or(0)
    }
}

/// Final decision stage.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DecisionMode {
    /// Binary: `y = (sum >= -qb)` — the bias moves to the comparison
    /// threshold (§2.3.3). `threshold = -qb` (may be ≤ 0 ⇒ constant 1).
    Binary { threshold: i64 },
    /// Multiclass: per-group non-negative biases (common offset already
    /// applied, §2.2.3) + argmax with ties breaking to the lower index.
    Multiclass { biases: Vec<u64> },
}

/// Pipeline configuration `[p0, p1, p2]` (§2.4): registers after the key
/// generator (`p0` ∈ {0,1}), after the tree layer (`p1` ∈ {0,1}), and `p2`
/// evenly-spaced register stages inside each adder tree.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pipeline {
    pub p0: usize,
    pub p1: usize,
    pub p2: usize,
}

impl Pipeline {
    pub fn new(p0: usize, p1: usize, p2: usize) -> Pipeline {
        assert!(p0 <= 1 && p1 <= 1, "p0/p1 are 0/1 flags");
        Pipeline { p0, p1, p2 }
    }

    /// Total register cuts = pipeline latency in cycles (II = 1).
    pub fn cuts(&self) -> usize {
        self.p0 + self.p1 + self.p2
    }
}

impl Default for Pipeline {
    fn default() -> Self {
        Pipeline { p0: 0, p1: 1, p2: 1 }
    }
}

/// A complete TreeLUT design.
#[derive(Clone, Debug)]
pub struct Design {
    pub name: String,
    /// Input feature count (quantized, each `w_feature` bits wide).
    pub n_features: usize,
    pub w_feature: u8,
    /// Key generator: sorted unique `(feature, threshold)` comparators.
    /// Empty when the key layer is bypassed (Table 6 DWN comparison mode —
    /// keys become direct circuit inputs).
    pub keys: Vec<(u32, u32)>,
    /// Number of key inputs when bypassed (otherwise `keys.len()`).
    pub n_key_inputs: usize,
    /// Whether the key generator layer is instantiated.
    pub keygen: bool,
    /// Tree logic, round-major over groups (tree `t` → group `t % groups`).
    pub trees: Vec<TreeLogic>,
    pub n_groups: usize,
    pub decision: DecisionMode,
    pub pipeline: Pipeline,
}

impl Design {
    /// Number of key signals (comparator outputs or direct inputs).
    pub fn n_keys(&self) -> usize {
        if self.keygen { self.keys.len() } else { self.n_key_inputs }
    }

    /// Trees of one group.
    pub fn trees_of_group(&self, g: usize) -> impl Iterator<Item = (usize, &TreeLogic)> + '_ {
        self.trees
            .iter()
            .enumerate()
            .filter(move |(i, _)| i % self.n_groups == g)
    }

    /// Output width in bits (1 for binary, `ceil(log2 N)` for multiclass).
    pub fn out_bits(&self) -> u32 {
        match &self.decision {
            DecisionMode::Binary { .. } => 1,
            DecisionMode::Multiclass { biases } => {
                (usize::BITS - (biases.len() - 1).leading_zeros()).max(1)
            }
        }
    }

    /// Structural sanity checks.
    pub fn validate(&self) -> anyhow::Result<()> {
        let nk = self.n_keys() as u32;
        for (ti, t) in self.trees.iter().enumerate() {
            let mut prev = 0u32;
            for (v, paths) in &t.cases {
                anyhow::ensure!(*v > 0, "tree {ti}: case for value 0");
                anyhow::ensure!(*v >= prev, "tree {ti}: cases not sorted");
                prev = *v;
                anyhow::ensure!(!paths.is_empty(), "tree {ti}: value {v} has no paths");
                for p in paths {
                    for (k, _) in &p.lits {
                        anyhow::ensure!(*k < nk, "tree {ti}: key {k} out of range {nk}");
                    }
                }
            }
        }
        anyhow::ensure!(self.trees.len() % self.n_groups == 0, "tree/group mismatch");
        if let DecisionMode::Multiclass { biases } = &self.decision {
            anyhow::ensure!(biases.len() == self.n_groups, "bias/group mismatch");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_design() -> Design {
        Design {
            name: "toy".into(),
            n_features: 2,
            w_feature: 2,
            keys: vec![(0, 1), (1, 2)],
            n_key_inputs: 2,
            keygen: true,
            trees: vec![TreeLogic {
                cases: vec![
                    (1, vec![Path { lits: vec![(0, false), (1, true)] }]),
                    (3, vec![Path { lits: vec![(0, true)] }]),
                ],
                out_bits: 2,
            }],
            n_groups: 1,
            decision: DecisionMode::Binary { threshold: 2 },
            pipeline: Pipeline::default(),
        }
    }

    #[test]
    fn validate_ok() {
        toy_design().validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_key() {
        let mut d = toy_design();
        d.trees[0].cases[0].1[0].lits[0].0 = 9;
        assert!(d.validate().is_err());
    }

    #[test]
    fn validate_rejects_zero_case() {
        let mut d = toy_design();
        d.trees[0].cases[0].0 = 0;
        assert!(d.validate().is_err());
    }

    #[test]
    fn out_bits_multiclass() {
        let mut d = toy_design();
        d.decision = DecisionMode::Multiclass { biases: vec![0; 5] };
        d.n_groups = 5;
        d.trees = (0..5).map(|_| d.trees[0].clone()).collect();
        assert_eq!(d.out_bits(), 3); // ceil(log2 5)
    }

    #[test]
    fn pipeline_cuts() {
        assert_eq!(Pipeline::new(0, 1, 1).cuts(), 2);
        assert_eq!(Pipeline::new(1, 1, 3).cuts(), 5);
    }
}
