//! RTL layer: the TreeLUT hardware architecture (paper §2.3) as a
//! synthesizable design.
//!
//! * [`ir`] — the architecture-level intermediate representation: key
//!   generator comparisons, per-tree path logic (unique-leaf selectors, the
//!   mux-cascade of Fig. 6b expressed as sum-of-paths boolean functions),
//!   per-group adder trees, the decision stage, and the pipeline cut
//!   configuration `[p0, p1, p2]` (§2.4).
//! * [`build`] — lowering a [`crate::quantize::QuantModel`] into the IR.
//! * [`verilog`] — the Verilog emitter (the original tool's output format).
//!
//! The same IR also drives [`crate::netlist`], the FPGA substrate that
//! stands in for Vivado (gate netlist → 6-LUT mapping → timing/area →
//! gate-level simulation), so the emitted Verilog and the simulated netlist
//! are two views of one structure.

pub mod ir;
pub mod build;
pub mod verilog;

pub use build::design_from_quant;
pub use ir::{Design, DecisionMode, Path, Pipeline, TreeLogic};
