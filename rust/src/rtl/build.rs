//! Lower a [`QuantModel`] into the architecture IR.
//!
//! This is the software half of the paper's tool (§3): traverse all trees,
//! extract the unique key set, express each tree as per-unique-leaf path
//! selectors (Fig. 6), move the binary bias to the comparison threshold
//! (§2.3.3), and shift multiclass biases non-negative (§2.2.3).

use super::ir::{DecisionMode, Design, Path, Pipeline, TreeLogic};
use crate::quantize::{QuantModel, QuantNode, QuantTree};
use std::collections::BTreeMap;

/// Build a [`Design`] from a quantized model.
///
/// `keygen = false` produces the Table 6 "DWN comparison" variant: the key
/// generator layer is bypassed and the circuit takes the key bits directly
/// as inputs (the comparisons are assumed performed offline, as DWN's
/// thermometer encoding is).
pub fn design_from_quant(
    name: &str,
    model: &QuantModel,
    pipeline: Pipeline,
    keygen: bool,
) -> Design {
    let keys = model.unique_comparisons();
    let key_index: BTreeMap<(u32, u32), u32> =
        keys.iter().enumerate().map(|(i, &k)| (k, i as u32)).collect();

    let trees: Vec<TreeLogic> = model.trees.iter().map(|t| tree_logic(t, &key_index)).collect();

    let decision = if model.n_groups == 1 {
        DecisionMode::Binary { threshold: -model.biases[0] }
    } else {
        let (biases, _offset) = model.nonneg_biases();
        DecisionMode::Multiclass { biases }
    };

    let d = Design {
        name: name.to_string(),
        n_features: model.n_features,
        w_feature: model.w_feature,
        n_key_inputs: keys.len(),
        keys,
        keygen,
        trees,
        n_groups: model.n_groups,
        decision,
        pipeline,
    };
    debug_assert!(d.validate().is_ok());
    d
}

/// Enumerate root-to-leaf paths grouped by unique non-zero leaf value.
fn tree_logic(tree: &QuantTree, key_index: &BTreeMap<(u32, u32), u32>) -> TreeLogic {
    let mut by_value: BTreeMap<u32, Vec<Path>> = BTreeMap::new();
    let mut stack: Vec<(u32, bool)> = Vec::new();
    walk(tree, 0, &mut stack, &mut by_value, key_index);
    let cases: Vec<(u32, Vec<Path>)> = by_value.into_iter().collect();
    let max = cases.last().map(|(v, _)| *v).unwrap_or(0);
    TreeLogic { cases, out_bits: crate::quantize::model::bits_for(max) }
}

fn walk(
    tree: &QuantTree,
    node: usize,
    stack: &mut Vec<(u32, bool)>,
    out: &mut BTreeMap<u32, Vec<Path>>,
    key_index: &BTreeMap<(u32, u32), u32>,
) {
    match &tree.nodes[node] {
        QuantNode::Leaf { value } => {
            if *value > 0 {
                out.entry(*value).or_default().push(Path { lits: stack.clone() });
            }
        }
        QuantNode::Split { feat, thresh, left, right } => {
            let k = key_index[&(*feat, *thresh)];
            stack.push((k, false)); // key = 0 → left (x < thresh)
            walk(tree, *left as usize, stack, out, key_index);
            stack.pop();
            stack.push((k, true)); // key = 1 → right
            walk(tree, *right as usize, stack, out, key_index);
            stack.pop();
        }
    }
}

/// Reference evaluator of a [`TreeLogic`] given key bits — used by tests to
/// check path extraction against [`QuantTree::predict`] semantics.
pub fn eval_tree_logic(t: &TreeLogic, keys: &[bool]) -> u32 {
    for (v, paths) in &t.cases {
        for p in paths {
            if p.lits.iter().all(|&(k, pos)| keys[k as usize] == pos) {
                return *v;
            }
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quantize::QuantNode as N;

    /// Paper Fig. 6a: root k5; left child k12 (1/3), right child k24 (1/0).
    /// Leaves: k5=0,k12=0 → 1; k5=0,k12=1 → 3; k5=1,k24=0 → 1 … build with
    /// 3 distinct keys: (5,1),(12,1),(24,1) become key ids 0,1,2.
    fn fig6_tree() -> QuantTree {
        QuantTree {
            nodes: vec![
                N::Split { feat: 5, thresh: 1, left: 1, right: 2 },
                N::Split { feat: 12, thresh: 1, left: 3, right: 4 },
                N::Split { feat: 24, thresh: 1, left: 5, right: 6 },
                N::Leaf { value: 1 },
                N::Leaf { value: 3 },
                N::Leaf { value: 1 },
                N::Leaf { value: 0 },
            ],
        }
    }

    fn fig6_model() -> QuantModel {
        QuantModel {
            trees: vec![fig6_tree()],
            n_groups: 1,
            biases: vec![-2],
            n_features: 32,
            w_feature: 1,
            w_tree: 2,
            scale: 1.0,
        }
    }

    #[test]
    fn fig6_paths_grouped_by_unique_leaf() {
        let d = design_from_quant("fig6", &fig6_model(), Pipeline::default(), true);
        let t = &d.trees[0];
        // Unique non-zero values: 1 (two paths — Fig. 6b's OR of two ANDs)
        // and 3 (one path).
        assert_eq!(t.cases.len(), 2);
        assert_eq!(t.cases[0].0, 1);
        assert_eq!(t.cases[0].1.len(), 2);
        assert_eq!(t.cases[1].0, 3);
        assert_eq!(t.cases[1].1.len(), 1);
        assert_eq!(t.out_bits, 2);
    }

    #[test]
    fn tree_logic_matches_tree_predict_exhaustively() {
        let model = fig6_model();
        let d = design_from_quant("fig6", &model, Pipeline::default(), true);
        // Keys: (5,1)=k0, (12,1)=k1, (24,1)=k2 (sorted by (feat,thresh)).
        for bits in 0..8u32 {
            let keys = [(bits & 1) != 0, (bits & 2) != 0, (bits & 4) != 0];
            let mut x = vec![0u16; 32];
            x[5] = keys[0] as u16;
            x[12] = keys[1] as u16;
            x[24] = keys[2] as u16;
            assert_eq!(
                eval_tree_logic(&d.trees[0], &keys),
                model.trees[0].predict(&x),
                "bits={bits:03b}"
            );
        }
    }

    #[test]
    fn binary_threshold_is_negated_bias() {
        let d = design_from_quant("b", &fig6_model(), Pipeline::default(), true);
        assert_eq!(d.decision, DecisionMode::Binary { threshold: 2 });
    }

    #[test]
    fn multiclass_biases_nonnegative() {
        let mut m = fig6_model();
        m.n_groups = 2;
        m.trees = vec![fig6_tree(), fig6_tree()];
        m.biases = vec![-7, -3];
        let d = design_from_quant("mc", &m, Pipeline::default(), true);
        match d.decision {
            DecisionMode::Multiclass { ref biases } => assert_eq!(biases, &vec![0, 4]),
            _ => panic!("expected multiclass"),
        }
    }

    #[test]
    fn bypass_mode_has_no_keygen() {
        let d = design_from_quant("dwn", &fig6_model(), Pipeline::default(), false);
        assert!(!d.keygen);
        assert_eq!(d.n_keys(), 3);
        d.validate().unwrap();
    }

    #[test]
    fn shared_keys_deduplicate() {
        // Two trees using the same comparison produce one key.
        let mut m = fig6_model();
        m.trees = vec![fig6_tree(), fig6_tree()];
        let d = design_from_quant("dup", &m, Pipeline::default(), true);
        assert_eq!(d.keys.len(), 3);
    }
}
