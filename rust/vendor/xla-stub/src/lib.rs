//! Offline stub of the `xla` crate (xla-rs PJRT bindings).
//!
//! The real runtime path loads an AOT-compiled HLO module through PJRT
//! (`PjRtClient::cpu` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`). That crate links the native `xla_extension` library, which is
//! not available in this offline build environment, so this stub provides
//! the exact API surface `treelut::runtime` uses with the same shapes and
//! ownership:
//!
//! * [`Literal`] is real: it stores typed host data plus dimensions, so
//!   tensor construction ([`Literal::vec1`], [`Literal::reshape`]) and the
//!   padding logic built on it stay fully testable.
//! * The PJRT entry points ([`PjRtClient::cpu`], [`PjRtClient::compile`],
//!   [`PjRtLoadedExecutable::execute`]) return [`Error::Unavailable`]: every
//!   caller in the repo is gated on `artifacts/manifest.txt` existing, so
//!   the error only surfaces when someone has artifacts but no real PJRT.
//!
//! To run against real PJRT, replace the `xla = { path = "vendor/xla-stub" }`
//! dependency in `rust/Cargo.toml` with the real `xla` crate (LaurentMazare's
//! xla-rs, pinned to xla_extension 0.5.1 — see `python/compile/aot.py` for
//! the HLO-text interchange rationale). No source changes are required.

use std::borrow::Borrow;
use std::fmt;

/// Stub error type; mirrors xla-rs in implementing [`std::error::Error`] so
/// `anyhow`'s `?` conversions work unchanged.
#[derive(Debug)]
pub enum Error {
    /// PJRT is not linked into this build.
    Unavailable(&'static str),
    /// A real error from the host-side tensor logic (shape mismatch, I/O).
    Msg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: built against the vendored xla stub (rust/vendor/xla-stub); \
                 link the real xla crate to execute PJRT artifacts"
            ),
            Error::Msg(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types [`Literal`] can hold.
#[derive(Clone, Debug, PartialEq)]
enum Buf {
    I32(Vec<i32>),
    I64(Vec<i64>),
    F32(Vec<f32>),
}

/// Host element types accepted by [`Literal::vec1`] / [`Literal::to_vec`].
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> Buf;
    fn unwrap(buf: &Buf) -> Option<Vec<Self>>;
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> Buf {
        Buf::I32(data)
    }
    fn unwrap(buf: &Buf) -> Option<Vec<Self>> {
        match buf {
            Buf::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i64 {
    fn wrap(data: Vec<Self>) -> Buf {
        Buf::I64(data)
    }
    fn unwrap(buf: &Buf) -> Option<Vec<Self>> {
        match buf {
            Buf::I64(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> Buf {
        Buf::F32(data)
    }
    fn unwrap(buf: &Buf) -> Option<Vec<Self>> {
        match buf {
            Buf::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host tensor: typed data plus dimensions.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    buf: Buf,
    dims: Vec<i64>,
}

impl Literal {
    fn len(&self) -> usize {
        match &self.buf {
            Buf::I32(v) => v.len(),
            Buf::I64(v) => v.len(),
            Buf::F32(v) => v.len(),
        }
    }

    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { buf: T::wrap(data.to_vec()), dims: vec![data.len() as i64] }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count != self.len() as i64 {
            return Err(Error::Msg(format!(
                "reshape to {dims:?} ({count} elements) from {} elements",
                self.len()
            )));
        }
        Ok(Literal { buf: self.buf.clone(), dims: dims.to_vec() })
    }

    /// Unwrap a single-element tuple result (execution results are lowered
    /// with `return_tuple=True`; see python/compile/aot.py). The stub never
    /// produces tuples, so this is unreachable in practice.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::Unavailable("Literal::to_tuple1 on a non-tuple stub literal"))
    }

    /// Copy the elements out as `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.buf)
            .ok_or_else(|| Error::Msg("literal element type mismatch".to_string()))
    }
}

/// Parsed HLO module text (the stub stores the text verbatim).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Read an HLO text artifact from disk.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Msg(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] always fails in the stub.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

/// A compiled executable. Unreachable in the stub (compilation fails first).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute on one batch of argument literals.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer produced by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Transfer the buffer to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_reshape_roundtrip() {
        let l = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert!(l.reshape(&[4, 3]).is_err());
    }

    #[test]
    fn type_mismatch_rejected() {
        let l = Literal::vec1(&[1.0f32]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.to_vec::<f32>().is_ok());
    }

    #[test]
    fn pjrt_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("xla stub"));
    }
}
