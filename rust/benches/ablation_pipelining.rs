//! Ablation of the paper's pipelining strategy (§2.4): sweep `[p0, p1, p2]`
//! and report Fmax / latency / FF cost, reproducing the claims that
//! (a) a single stage after the trees or inside the adder tree gives most
//! of the Fmax, and (b) more stages trade latency for frequency.
//!
//! Run: `cargo bench --bench ablation_pipelining [-- --rows N]`

use treelut::exp::configs::{default_rows, design_point};
use treelut::exp::table::Table;
use treelut::exp::{run_design_point, RunOptions};
use treelut::netlist::{build_netlist, map_luts, CostReport, TimingModel};
use treelut::rtl::{design_from_quant, Pipeline};
use treelut::util::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let rows_override = args.opt("rows").map(|r| r.parse::<usize>().unwrap());
    args.finish()?;

    for (dataset, variant) in [("jsc", "I"), ("nid", "I"), ("mnist", "II")] {
        let dp = design_point(dataset, variant).unwrap();
        let rows = rows_override.unwrap_or_else(|| default_rows(dataset)).min(12_000);
        // Train once; rebuild the netlist per pipeline config.
        let r = run_design_point(
            &dp,
            &RunOptions { rows, seed: 7, bypass_keygen: false, simulate: false },
        )?;
        println!(
            "== pipelining ablation [{dataset} {variant}] (paper uses [{},{},{}]) ==",
            dp.pipeline.p0, dp.pipeline.p1, dp.pipeline.p2
        );
        let mut t = Table::new(&[
            "[p0,p1,p2]", "cuts", "LUT", "FF", "Fmax(MHz)", "Lat(ns)", "AxD", "note",
        ]);
        for (p0, p1, p2) in [
            (0, 0, 0),
            (1, 0, 0),
            (0, 1, 0),
            (0, 0, 1),
            (0, 1, 1),
            (1, 1, 1),
            (1, 1, 2),
            (1, 1, 4),
        ] {
            let pipeline = Pipeline::new(p0, p1, p2);
            let design = design_from_quant("ablate", &r.quant, pipeline, true);
            let built = build_netlist(&design);
            let map = map_luts(&built.net);
            let cost = CostReport::evaluate(&map, built.cuts, &TimingModel::default());
            let note = if pipeline == dp.pipeline { "paper config" } else { "" };
            t.row(&[
                format!("[{p0},{p1},{p2}]"),
                built.cuts.to_string(),
                cost.luts.to_string(),
                cost.ffs.to_string(),
                format!("{:.0}", cost.fmax_mhz),
                format!("{:.2}", cost.latency_ns),
                format!("{:.2e}", cost.area_delay),
                note.into(),
            ]);
        }
        println!("{}", t.render());
    }
    println!("expected shape (paper 2.4): combinational [0,0,0] has the lowest Fmax;");
    println!("one stage after trees or in the adder tree recovers most of it; extra");
    println!("stages keep raising Fmax with diminishing returns while latency grows.");
    Ok(())
}
