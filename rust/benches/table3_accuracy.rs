//! Regenerates paper **Table 3** (accuracy before/after quantization) and
//! **Table 4** (dataset specifications) on the synthetic stand-in datasets.
//!
//! Run: `cargo bench --bench table3_accuracy [-- --rows N]`

use treelut::data::synth;
use treelut::exp::table::{pct, Table};
use treelut::exp::{design_points, run_design_point, RunOptions};
use treelut::util::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let rows_override = args.opt("rows").map(|r| r.parse::<usize>().unwrap());
    args.finish()?;

    println!("== Table 4: dataset specifications ==");
    let mut t4 = Table::new(&["Dataset", "Input Features", "Classes"]);
    for name in ["mnist", "jsc", "nid"] {
        let ds = synth::by_name(name, 100, 7).unwrap();
        t4.row(&[name.into(), ds.n_features.to_string(), ds.n_classes.to_string()]);
    }
    println!("{}", t4.render());

    println!("== Table 3: accuracy before/after quantization ==");
    println!("(paper values: MNIST 96.9→96.6 / 96.5→95.6, JSC 75.7→75.6 / 74.8→74.6,");
    println!(" NID 92.0→92.7 / 91.7→91.5; ours measured on calibrated synthetic data)\n");
    let mut t3 = Table::new(&[
        "Dataset", "Method", "Before Quant", "After Quant", "Gate-level sim", "Paper After",
    ]);
    for dp in design_points() {
        let rows =
            rows_override.unwrap_or_else(|| treelut::exp::configs::default_rows(dp.dataset));
        let r = run_design_point(
            &dp,
            &RunOptions { rows, seed: 7, bypass_keygen: false, simulate: true },
        )?;
        let gate = r.acc_netlist.expect("simulate on");
        assert!(
            (gate - r.acc_quant).abs() < 1e-12,
            "gate-level sim diverged from quantized predictor"
        );
        t3.row(&[
            dp.dataset.into(),
            dp.label.to_string(),
            pct(r.acc_float),
            pct(r.acc_quant),
            pct(gate),
            pct(dp.paper_accuracy),
        ]);
    }
    println!("{}", t3.render());
    Ok(())
}
