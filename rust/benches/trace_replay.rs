//! Open-loop trace replay through the network ingress: the full
//! wire path (frame encode → TCP → `run_listener` → admission →
//! registry pool → reply frame) under realistic arrival processes,
//! targeting the 10^5–10^6 rows/s regime of the paper's serving
//! motivation.
//!
//! Two trace shapes, both pre-generated so the replay measures the
//! server and not the generator:
//! * **poisson** — memoryless arrivals at a fixed offered rate (the
//!   steady-state baseline);
//! * **diurnal** — a bursty sinusoidal rate sweep between 0.25x and
//!   1.75x of the nominal rate over three periods (the load-tracking
//!   shape: admission and batching see sustained troughs and peaks,
//!   not an average).
//!
//! The driver is bucketed: arrivals are grouped into 1 ms buckets and
//! each bucket's frames are written in one burst at its deadline —
//! per-frame sleep/wake cannot pace 10^5+ rows/s, and the bucket write
//! is exactly the coalesced shape a real high-rate client produces.
//!
//! Reported per shape: offered vs achieved rows/s, server-side
//! latency (enqueue → reply, from the reply frame) and end-to-end
//! client latency, NACK counts by the ingress ladder, and the pool's
//! mean batch size.
//!
//! Run: `cargo bench --bench trace_replay [-- --requests N --rps R]`

use std::collections::VecDeque;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use treelut::coordinator::ingress::{
    self, encode_submit, AdmissionConfig, FrameClient, Ingress, Response,
};
use treelut::coordinator::{
    BatchPolicy, DispatchPolicy, ModelArtifact, ModelRegistry, OverloadPolicy, RegistryServer,
};
use treelut::data::synth;
use treelut::exp::configs::design_point;
use treelut::gbdt::train;
use treelut::quantize::{quantize_leaves, FeatureQuantizer, FlatForest, QuantModel};
use treelut::util::{Args, Rng, Summary};

/// One pre-generated request: arrival offset, tenant, row.
struct Event {
    at: Duration,
    tenant: u16,
    row: usize,
}

/// Memoryless arrivals at `rate` rows/s.
fn poisson_trace(n: usize, rate: f64, n_rows: usize, seed: u64) -> Vec<Event> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|i| {
            t += -(1.0 - rng.f64()).ln() / rate;
            Event {
                at: Duration::from_secs_f64(t),
                tenant: (i % 2) as u16,
                row: rng.below(n_rows),
            }
        })
        .collect()
}

/// Bursty diurnal arrivals: instantaneous rate `rate * (1 + 0.75 sin)`
/// swept over three full periods across the nominal replay window, so
/// the pool sees troughs at 0.25x and peaks at 1.75x — same mean offered
/// load as the Poisson trace, very different instantaneous shape.
fn diurnal_trace(n: usize, rate: f64, n_rows: usize, seed: u64) -> Vec<Event> {
    let mut rng = Rng::new(seed);
    let window = n as f64 / rate;
    let period = window / 3.0;
    let mut t = 0.0f64;
    (0..n)
        .map(|i| {
            let inst =
                rate * (1.0 + 0.75 * (2.0 * std::f64::consts::PI * t / period).sin()).max(0.05);
            t += -(1.0 - rng.f64()).ln() / inst;
            Event {
                at: Duration::from_secs_f64(t),
                tenant: (i % 2) as u16,
                row: rng.below(n_rows),
            }
        })
        .collect()
}

struct ReplayOutcome {
    wall: f64,
    replies: usize,
    nacks: usize,
    server_lat: Summary,
    e2e_lat: Summary,
}

/// Replay `trace` against the listener at `addr` and collect every
/// response. Writer thread paces 1 ms buckets; reader thread drains.
fn replay(
    addr: std::net::SocketAddr,
    trace: &[Event],
    rows: &Arc<Vec<Vec<u16>>>,
) -> anyhow::Result<ReplayOutcome> {
    // Pre-encode each 1 ms bucket's frames into one write buffer.
    let mut buckets: VecDeque<(Duration, Vec<u8>)> = VecDeque::new();
    let mut sent_at: Vec<Duration> = Vec::with_capacity(trace.len());
    for (req_id, ev) in trace.iter().enumerate() {
        let slot = Duration::from_millis(ev.at.as_millis() as u64);
        if buckets.back().map(|(t, _)| *t != slot).unwrap_or(true) {
            buckets.push_back((slot, Vec::new()));
        }
        encode_submit(&mut buckets.back_mut().unwrap().1, req_id as u64, ev.tenant, &rows[ev.row]);
        sent_at.push(slot); // the bucket deadline is the intended send time
    }

    let mut client = FrameClient::connect(addr)?;
    let mut wstream: TcpStream = client.stream().try_clone()?;
    let t0 = Instant::now();
    let writer = std::thread::spawn(move || -> anyhow::Result<Duration> {
        let mut lag = Duration::ZERO;
        while let Some((at, buf)) = buckets.pop_front() {
            let now = t0.elapsed();
            if at > now {
                std::thread::sleep(at - now);
            } else {
                lag = lag.max(now - at);
            }
            wstream.write_all(&buf)?;
        }
        Ok(lag)
    });

    let mut server_lat = Vec::with_capacity(trace.len());
    let mut e2e_lat = Vec::with_capacity(trace.len());
    let mut nacks = 0usize;
    for _ in 0..trace.len() {
        match client.recv()? {
            Response::Reply { req_id, latency_us, .. } => {
                server_lat.push(latency_us as f64 * 1e-6);
                e2e_lat.push((t0.elapsed() - sent_at[req_id as usize]).as_secs_f64());
            }
            Response::Nack { .. } => nacks += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let lag = writer.join().expect("writer thread")?;
    if lag > Duration::from_millis(50) {
        println!("  (writer fell {lag:?} behind the trace at peak)");
    }
    Ok(ReplayOutcome {
        wall,
        replies: server_lat.len(),
        nacks,
        server_lat: Summary::of(&server_lat),
        e2e_lat: Summary::of(&e2e_lat),
    })
}

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let requests = args.get_as::<usize>("requests", 200_000);
    let rps = args.get_as::<f64>("rps", 200_000.0);
    let shards = args.get_as::<usize>("shards", 4);
    let seed = args.get_as::<u64>("seed", 1);
    args.finish()?;

    // A light model (jsc II: 16 features) so the wire path — not tree
    // descent — is the bottleneck under test.
    let dp = design_point("jsc", "II").unwrap();
    let ds = synth::jsc_like(10_000, 7);
    let (train_ds, test_ds) = ds.split(0.2, 1);
    let fq = FeatureQuantizer::fit(&train_ds, dp.w_feature);
    let btrain = fq.transform(&train_ds);
    println!("training jsc (II) model ({} rows)...", train_ds.n_rows);
    let model = train(&btrain, &train_ds.y, train_ds.n_classes, &dp.params, dp.w_feature)?;
    let (quant, _): (QuantModel, _) = quantize_leaves(&model, dp.w_tree);
    let btest = fq.transform(&test_ds);
    let rows: Arc<Vec<Vec<u16>>> =
        Arc::new((0..btest.n_rows).map(|i| btest.row(i).to_vec()).collect());

    // Two tenants of the same trained model behind one pool.
    let registry = Arc::new(ModelRegistry::new());
    registry.register("jsc-a", ModelArtifact::Flat(Arc::new(FlatForest::compile(&quant)?)))?;
    registry.register("jsc-b", ModelArtifact::Flat(Arc::new(FlatForest::compile(&quant)?)))?;
    let policy = BatchPolicy {
        max_batch: 256,
        max_wait: Duration::from_micros(200),
        queue_cap: usize::MAX,
        overload: OverloadPolicy::Block,
    };
    let server = Arc::new(RegistryServer::start(
        Arc::clone(&registry),
        policy,
        shards,
        DispatchPolicy::P2c,
    )?);
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let ing = Arc::new(Ingress::new(AdmissionConfig::default()));
    let stop = Arc::new(AtomicBool::new(false));
    let lt = {
        let (backend, ing, stop) = (
            Arc::clone(&server) as Arc<dyn ingress::IngressBackend>,
            Arc::clone(&ing),
            Arc::clone(&stop),
        );
        std::thread::spawn(move || ingress::run_listener(listener, backend, ing, stop))
    };

    println!(
        "\n== trace replay: {requests} rows @ nominal {rps:.0} rows/s, {shards} shards, 2 \
         tenants =="
    );
    for shape in ["poisson", "diurnal"] {
        let trace = match shape {
            "poisson" => poisson_trace(requests, rps, rows.len(), seed),
            _ => diurnal_trace(requests, rps, rows.len(), seed ^ 0xd1a2),
        };
        let out = replay(addr, &trace, &rows)?;
        let srv = &out.server_lat;
        let e2e = &out.e2e_lat;
        println!(
            "{shape:>8}: {:.0} rows/s achieved ({:.0} offered), {} replies, {} nacks\n          \
             server p50 {:.0}us p99 {:.0}us | e2e p50 {:.0}us p99 {:.0}us max {:.1}ms",
            out.replies as f64 / out.wall,
            rps,
            out.replies,
            out.nacks,
            srv.p50 * 1e6,
            srv.p99 * 1e6,
            e2e.p50 * 1e6,
            e2e.p99 * 1e6,
            e2e.max * 1e3,
        );
        anyhow::ensure!(out.replies + out.nacks == requests, "response for every frame");
        anyhow::ensure!(out.nacks == 0, "un-throttled replay must not shed");
    }
    let s = server.server().stats();
    println!(
        "pool: {} batches, mean batch {:.1} rows; ingress: {} frames, {} accepted",
        s.batches.load(Ordering::Relaxed),
        s.mean_batch(),
        ing.stats.frames.load(Ordering::Relaxed),
        ing.stats.accepted.load(Ordering::Relaxed),
    );

    stop.store(true, Ordering::Relaxed);
    lt.join().expect("listener thread")?;
    Arc::try_unwrap(server).unwrap_or_else(|_| panic!("pool still shared")).shutdown();
    Ok(())
}
