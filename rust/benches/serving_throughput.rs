//! Serving-path benchmark: throughput/latency of the L3 coordinator over
//! the AOT-compiled PJRT executable (the repo's "inference acceleration"
//! runtime), swept over offered load and batching policy.
//!
//! Also reports the raw engine execute rate (batch=64) and the pure-Rust
//! integer predictor as the software baseline — the analogue of the paper's
//! throughput motivation.
//!
//! Requires `make artifacts`. Run: `cargo bench --bench serving_throughput`

use std::path::PathBuf;
use std::time::Duration;

use treelut::coordinator::{BatchPolicy, CpuExecutor, Server, ServingReport};
use treelut::data::synth;
use treelut::exp::configs::design_point;
use treelut::exp::table::Table;
use treelut::gbdt::train;
use treelut::quantize::{quantize_leaves, FeatureQuantizer, QuantModel};
use treelut::runtime::{ArtifactConfig, Engine, Manifest, ModelTensors};
use treelut::util::{Args, Rng, Timer};

fn poisson_run(
    server: &Server,
    rows: &treelut::gbdt::histogram::BinnedMatrix,
    n_requests: usize,
    rps: f64,
) -> anyhow::Result<ServingReport> {
    let mut rng = Rng::new(17);
    let t0 = Timer::start();
    let mut pending = Vec::with_capacity(n_requests);
    let mut next = std::time::Instant::now();
    for i in 0..n_requests {
        next += Duration::from_secs_f64(rng.exp(rps));
        let now = std::time::Instant::now();
        if next > now {
            std::thread::sleep(next - now);
        }
        pending.push(server.submit(rows.row(i % rows.n_rows).to_vec())?);
    }
    let mut lats = Vec::with_capacity(n_requests);
    for rx in pending {
        lats.push(rx.recv()??.latency.as_secs_f64());
    }
    Ok(ServingReport::from_latencies(&lats, t0.secs(), server.stats().mean_batch(), Some(rps)))
}

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let n_requests = args.get_as::<usize>("requests", 3_000);
    args.finish()?;

    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.txt").exists() {
        println!("SKIP serving_throughput: artifacts/ missing (run `make artifacts`)");
        return Ok(());
    }
    let manifest = Manifest::load(&artifacts)?;
    let cfg = manifest.get("jsc")?.clone();

    // Train the JSC (II) model once.
    let dp = design_point("jsc", "II").unwrap();
    let ds = synth::jsc_like(10_000, 7);
    let (train_ds, test_ds) = ds.split(0.2, 1);
    let fq = FeatureQuantizer::fit(&train_ds, dp.w_feature);
    let btrain = fq.transform(&train_ds);
    let model = train(&btrain, &train_ds.y, train_ds.n_classes, &dp.params, dp.w_feature)?;
    let (quant, _) = quantize_leaves(&model, dp.w_tree);
    let btest = fq.transform(&test_ds);

    // Raw engine execute rate (no coordinator).
    {
        let tensors = ModelTensors::from_quant(&quant, &cfg)?;
        let engine = Engine::load(&artifacts, &cfg, tensors)?;
        let rows: Vec<&[u16]> = (0..cfg.batch).map(|i| btest.row(i)).collect();
        let iters = 200;
        let samples = treelut::util::timer::bench_loop(iters, || engine.predict(&rows).unwrap());
        let s = treelut::util::Summary::of(&samples);
        println!(
            "raw engine (PJRT, batch={}): {:.0} exec/s -> {:.0} rows/s (p50 {:.0}us/batch)",
            cfg.batch,
            1.0 / s.p50,
            cfg.batch as f64 / s.p50,
            s.p50 * 1e6
        );
    }
    // Software baseline: integer predictor.
    {
        let iters = 200;
        let rows: Vec<&[u16]> = (0..cfg.batch).map(|i| btest.row(i)).collect();
        let samples = treelut::util::timer::bench_loop(iters, || {
            rows.iter().map(|r| quant.predict_class(r)).collect::<Vec<_>>()
        });
        let s = treelut::util::Summary::of(&samples);
        println!(
            "integer predictor (pure rust, batch={}): {:.0} rows/s",
            cfg.batch,
            cfg.batch as f64 / s.p50
        );
    }

    // Coordinator sweep: offered load x max_wait.
    println!("\n== coordinator sweep (PJRT engine, Poisson open-loop) ==");
    let mut t = Table::new(&["rps", "max_wait", "throughput", "batch", "p50", "p99"]);
    for rps in [1_000.0, 4_000.0, 16_000.0] {
        for wait_us in [100u64, 500, 2_000] {
            let (q2, c2, a2) = (quant.clone(), cfg.clone(), artifacts.clone());
            let server = Server::start_with(
                move || {
                    let tensors = ModelTensors::from_quant(&q2, &c2)?;
                    Engine::load(&a2, &c2, tensors)
                },
                BatchPolicy {
                    max_batch: cfg.batch,
                    max_wait: Duration::from_micros(wait_us),
                },
            )?;
            let rep = poisson_run(&server, &btest, n_requests, rps)?;
            t.row(&[
                format!("{rps:.0}"),
                format!("{wait_us}us"),
                format!("{:.0}/s", rep.throughput),
                format!("{:.1}", rep.mean_batch),
                format!("{:.0}us", rep.latency.p50 * 1e6),
                format!("{:.0}us", rep.latency.p99 * 1e6),
            ]);
            server.shutdown();
        }
    }
    println!("{}", t.render());

    // CPU-executor coordinator (no PJRT) as the L3-overhead control.
    println!("== coordinator with pure-Rust executor (L3 overhead control) ==");
    let qm: QuantModel = quant.clone();
    let cfg2: ArtifactConfig = cfg.clone();
    let server = Server::start(
        CpuExecutor { model: qm, max_batch: cfg2.batch },
        BatchPolicy { max_batch: cfg2.batch, max_wait: Duration::from_micros(100) },
    );
    let rep = poisson_run(&server, &btest, n_requests, 16_000.0)?;
    println!("cpu executor @16k rps: {}", rep.render());
    server.shutdown();
    Ok(())
}
