//! Serving-path benchmark: throughput/latency of the L3 coordinator,
//! swept over executor kind (enum-walking `CpuExecutor` vs flat-forest
//! `FlatExecutor`), shard count, batching policy, and dispatch policy
//! (blind round-robin vs power-of-two-choices + work stealing) — the
//! software analogue of the paper's throughput motivation (II = 1, one
//! prediction per cycle).
//!
//! Two load shapes per configuration:
//! * **firehose** — submit every request as fast as possible and measure
//!   completion rows/sec (capacity);
//! * **Poisson open loop** — measure p50/p99 latency at a fixed offered
//!   load.
//!
//! Two headline checks:
//! * an N-shard `FlatForest` pool must beat the single-worker
//!   `CpuExecutor` baseline on rows/sec at the same batch policy;
//! * with one artificially slow shard (the **slow-shard sweep**), the
//!   p2c+stealing pool must beat blind round-robin on Poisson p99 at equal
//!   offered load — the PolyLUT-Add-style tail-latency comparison.
//!
//! The **netlist executor sweep** additionally serves the hardware-accurate
//! path (`NetlistExecutor`: the mapped gate-level circuit, 64 rows per
//! machine word) against the flat forest at equal load, reporting the
//! circuit's LUT/FF/cut structure and the 64-lane occupancy (rows mod 64
//! padding waste) real traffic achieved.
//!
//! The PJRT section (AOT artifact engine) additionally runs when
//! `artifacts/manifest.txt` exists (`make artifacts`).
//!
//! Run: `cargo bench --bench serving_throughput [-- --requests N --rps R]`

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use treelut::coordinator::{
    BatchExecutor, BatchPolicy, CompiledNetlist, CpuExecutor, DispatchPolicy, FlatExecutor,
    LaneStats, ModelArtifact, ModelRegistry, OverloadPolicy, RegistryServer, Server, ServingReport,
    SubmitError, SwapCheck,
};
use treelut::data::synth;
use treelut::exp::configs::design_point;
use treelut::exp::table::Table;
use treelut::gbdt::histogram::BinnedMatrix;
use treelut::gbdt::train;
use treelut::netlist::{BuildOpts, LANES};
use treelut::quantize::{quantize_leaves, FeatureQuantizer, FlatForest, QuantModel};
use treelut::runtime::{Engine, Manifest, ModelTensors};
use treelut::util::{Args, Rng, Summary, Timer};

/// Snapshot of the batch/steal counters, for per-run deltas (the same
/// server serves several runs; lifetime means would mix them).
struct StatSnapshot {
    batches: u64,
    rows: u64,
    steals: u64,
    stolen_jobs: u64,
}

fn snapshot(server: &Server) -> StatSnapshot {
    let s = server.stats();
    StatSnapshot {
        batches: s.batches.load(Ordering::Relaxed),
        rows: s.rows_executed.load(Ordering::Relaxed),
        steals: s.steals.load(Ordering::Relaxed),
        stolen_jobs: s.stolen_jobs.load(Ordering::Relaxed),
    }
}

fn mean_batch_since(server: &Server, before: &StatSnapshot) -> f64 {
    let after = snapshot(server);
    let batches = after.batches - before.batches;
    if batches == 0 { 0.0 } else { (after.rows - before.rows) as f64 / batches as f64 }
}

/// Attach pool metadata + per-run steal deltas to a report.
fn finish_report(server: &Server, before: &StatSnapshot, report: ServingReport) -> ServingReport {
    let after = snapshot(server);
    report
        .with_shards(server.n_shards())
        .with_dispatch(server.dispatch())
        .with_steals(after.steals - before.steals, after.stolen_jobs - before.stolen_jobs)
}

/// Open-loop Poisson arrivals at `rps`; returns the latency report. On
/// the unbounded pools this section uses, shedding is impossible, so this
/// is just [`poisson_run_admitting`] under its original name.
fn poisson_run(
    server: &Server,
    rows: &BinnedMatrix,
    n_requests: usize,
    rps: f64,
) -> anyhow::Result<ServingReport> {
    poisson_run_admitting(server, rows, n_requests, rps)
}

/// Open-loop Poisson arrivals that tolerate admission control: shed-new
/// refusals and shed-oldest victims are counted instead of aborting the
/// run, and the report's latency summary covers *served* jobs only (the
/// point of shedding is exactly that those jobs stay fast).
fn poisson_run_admitting(
    server: &Server,
    rows: &BinnedMatrix,
    n_requests: usize,
    rps: f64,
) -> anyhow::Result<ServingReport> {
    let before = snapshot(server);
    let sheds0 = server.stats().sheds.load(Ordering::Relaxed);
    let full0 = server.stats().queue_full.load(Ordering::Relaxed);
    let redirects0 = server.stats().redirects.load(Ordering::Relaxed);
    let mut rng = Rng::new(17);
    let t0 = Timer::start();
    let mut pending = Vec::with_capacity(n_requests);
    let mut next = std::time::Instant::now();
    for i in 0..n_requests {
        next += Duration::from_secs_f64(rng.exp(rps));
        let now = std::time::Instant::now();
        if next > now {
            std::thread::sleep(next - now);
        }
        match server.submit(rows.row(i % rows.n_rows).to_vec()) {
            Ok(rx) => pending.push(rx),
            Err(e)
                if matches!(
                    e.downcast_ref::<SubmitError>(),
                    Some(SubmitError::QueueFull { .. })
                ) => {}
            Err(e) => return Err(e),
        }
    }
    let mut lats = Vec::with_capacity(pending.len());
    for rx in pending {
        match rx.recv()? {
            Ok(reply) => lats.push(reply.latency.as_secs_f64()),
            Err(e)
                if matches!(
                    e.downcast_ref::<SubmitError>(),
                    Some(SubmitError::Shed { .. })
                ) => {}
            Err(e) => return Err(e),
        }
    }
    let mean_batch = mean_batch_since(server, &before);
    let rep = ServingReport::from_latencies(&lats, t0.secs(), mean_batch, Some(rps));
    Ok(finish_report(server, &before, rep).with_admission(
        server.stats().sheds.load(Ordering::Relaxed) - sheds0,
        server.stats().queue_full.load(Ordering::Relaxed) - full0,
        server.stats().redirects.load(Ordering::Relaxed) - redirects0,
    ))
}

/// Closed-loop firehose: submit everything immediately, measure capacity.
fn firehose_run(
    server: &Server,
    rows: &BinnedMatrix,
    n_requests: usize,
) -> anyhow::Result<ServingReport> {
    let before = snapshot(server);
    let t0 = Timer::start();
    let mut pending = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        pending.push(server.submit(rows.row(i % rows.n_rows).to_vec())?);
    }
    let mut lats = Vec::with_capacity(n_requests);
    for rx in pending {
        lats.push(rx.recv()??.latency.as_secs_f64());
    }
    let mean_batch = mean_batch_since(server, &before);
    let rep = ServingReport::from_latencies(&lats, t0.secs(), mean_batch, None);
    Ok(finish_report(server, &before, rep))
}

/// `FlatExecutor` with an artificial per-batch stall — the "one slow or
/// stalling shard" the dispatch policies are compared against.
struct SlowExecutor {
    inner: FlatExecutor,
    extra: Duration,
}

impl BatchExecutor for SlowExecutor {
    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }
    fn n_features(&self) -> usize {
        self.inner.n_features()
    }
    fn execute(&self, rows: &[&[u16]]) -> anyhow::Result<Vec<u32>> {
        if !self.extra.is_zero() {
            std::thread::sleep(self.extra);
        }
        self.inner.execute(rows)
    }
}

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let n_requests = args.get_as::<usize>("requests", 20_000);
    let rps = args.get_as::<f64>("rps", 20_000.0);
    let rows = args.get_as::<usize>("rows", 4_000);
    args.finish()?;

    // A deliberately heavy model (MNIST (I): 300 trees of depth <= 5 over
    // 784 features) so serving is executor-bound, not submission-bound.
    let dp = design_point("mnist", "I").unwrap();
    let ds = synth::mnist_like(rows, 7);
    let (train_ds, test_ds) = ds.split(0.2, 1);
    let fq = FeatureQuantizer::fit(&train_ds, dp.w_feature);
    let btrain = fq.transform(&train_ds);
    println!("training mnist (I) model ({} rows)...", train_ds.n_rows);
    let model = train(&btrain, &train_ds.y, train_ds.n_classes, &dp.params, dp.w_feature)?;
    let (quant, _) = quantize_leaves(&model, dp.w_tree);
    let btest = fq.transform(&test_ds);
    const MAX_BATCH: usize = LANES;

    // --- Raw (coordinator-free) predictor rates --------------------------
    let forest = FlatForest::compile(&quant)?;
    let batch_rows: Vec<&[u16]> = (0..MAX_BATCH).map(|i| btest.row(i % btest.n_rows)).collect();
    let iters = 50;
    let enum_rate = {
        let samples = treelut::util::timer::bench_loop(iters, || {
            batch_rows.iter().map(|r| quant.predict_class(r)).collect::<Vec<_>>()
        });
        MAX_BATCH as f64 / Summary::of(&samples).p50
    };
    let flat_rate = {
        let samples =
            treelut::util::timer::bench_loop(iters, || forest.predict_batch(&batch_rows));
        MAX_BATCH as f64 / Summary::of(&samples).p50
    };
    println!(
        "raw predictor (batch={MAX_BATCH}): enum-tree {enum_rate:.0} rows/s, \
         flat-forest {flat_rate:.0} rows/s ({:.2}x)",
        flat_rate / enum_rate
    );

    // --- Coordinator sweep: executor x shards x policy x dispatch ---------
    println!("\n== coordinator sweep (firehose capacity + Poisson @ {rps:.0} rps) ==");
    let mut t = Table::new(&[
        "executor", "dispatch", "shards", "max_wait", "rows/s", "batch", "p50", "p99", "steals",
    ]);
    let mut cpu1_capacity = 0.0f64; // single-worker CpuExecutor baseline
    let mut flat_sharded_capacity = 0.0f64; // best sharded FlatForest
    for &shards in &[1usize, 2, 4] {
        // Dispatch only matters with siblings to choose between.
        let dispatches: &[DispatchPolicy] = if shards == 1 {
            &[DispatchPolicy::RoundRobin]
        } else {
            &[DispatchPolicy::RoundRobin, DispatchPolicy::P2c]
        };
        for &dispatch in dispatches {
            for &wait_us in &[100u64, 1_000] {
                for kind in ["cpu", "flat"] {
                    let policy = BatchPolicy {
                        max_batch: MAX_BATCH,
                        max_wait: Duration::from_micros(wait_us),
                        ..BatchPolicy::default()
                    };
                    let server = if kind == "cpu" {
                        let q = quant.clone();
                        Server::start_pool_dispatch(
                            move |_shard| {
                                Ok(CpuExecutor { model: q.clone(), max_batch: MAX_BATCH })
                            },
                            policy,
                            shards,
                            dispatch,
                        )?
                    } else {
                        // Compile once (done above), clone the tables per shard.
                        let fo = forest.clone();
                        Server::start_pool_dispatch(
                            move |_shard| {
                                Ok(FlatExecutor { forest: fo.clone(), max_batch: MAX_BATCH })
                            },
                            policy,
                            shards,
                            dispatch,
                        )?
                    };
                    let cap = firehose_run(&server, &btest, n_requests)?;
                    let lat = poisson_run(&server, &btest, n_requests.min(2_000), rps)?;
                    if kind == "cpu" && shards == 1 && wait_us == 100 {
                        cpu1_capacity = cap.throughput;
                    }
                    if kind == "flat" && shards > 1 && wait_us == 100 {
                        flat_sharded_capacity = flat_sharded_capacity.max(cap.throughput);
                    }
                    t.row(&[
                        kind.into(),
                        dispatch.label().into(),
                        shards.to_string(),
                        format!("{wait_us}us"),
                        format!("{:.0}", cap.throughput),
                        format!("{:.1}", cap.mean_batch),
                        format!("{:.0}us", lat.latency.p50 * 1e6),
                        format!("{:.0}us", lat.latency.p99 * 1e6),
                        (cap.steals + lat.steals).to_string(),
                    ]);
                    server.shutdown();
                }
            }
        }
    }
    println!("{}", t.render());
    println!(
        "headline: sharded FlatForest {flat_sharded_capacity:.0} rows/s vs single-worker \
         CpuExecutor {cpu1_capacity:.0} rows/s at equal policy -> {:.2}x {}",
        flat_sharded_capacity / cpu1_capacity,
        if flat_sharded_capacity > cpu1_capacity { "(sharded flat wins)" } else { "(REGRESSION)" }
    );

    // --- Slow-shard sweep: dispatch policy under skew ---------------------
    // One of four shards stalls ~10x a typical batch on every execute; at
    // equal offered load, depth-aware dispatch + stealing must keep the
    // tail down where blind round-robin feeds the stall every 4th request.
    let extra = Duration::from_secs_f64(10.0 * MAX_BATCH as f64 / flat_rate)
        .max(Duration::from_millis(2));
    println!(
        "\n== slow-shard sweep: shard 0 stalls {:.1}ms/batch, 4 shards, Poisson @ {rps:.0} rps ==",
        extra.as_secs_f64() * 1e3
    );
    let mut t = Table::new(&["dispatch", "rows/s", "batch", "p50", "p99", "steals(jobs)"]);
    let mut p99 = [0.0f64; 2];
    for (i, dispatch) in [DispatchPolicy::RoundRobin, DispatchPolicy::P2c].into_iter().enumerate()
    {
        let fo = forest.clone();
        let server = Server::start_pool_dispatch(
            move |shard| {
                Ok(SlowExecutor {
                    inner: FlatExecutor { forest: fo.clone(), max_batch: MAX_BATCH },
                    extra: if shard == 0 { extra } else { Duration::ZERO },
                })
            },
            BatchPolicy {
                max_batch: MAX_BATCH,
                max_wait: Duration::from_micros(100),
                ..BatchPolicy::default()
            },
            4,
            dispatch,
        )?;
        let rep = poisson_run(&server, &btest, n_requests.min(4_000), rps)?;
        p99[i] = rep.latency.p99;
        t.row(&[
            dispatch.label().into(),
            format!("{:.0}", rep.throughput),
            format!("{:.1}", rep.mean_batch),
            format!("{:.0}us", rep.latency.p50 * 1e6),
            format!("{:.0}us", rep.latency.p99 * 1e6),
            format!("{} ({})", rep.steals, rep.stolen_jobs),
        ]);
        server.shutdown();
    }
    println!("{}", t.render());
    println!(
        "headline: p2c+stealing p99 {:.0}us vs round-robin p99 {:.0}us under one slow shard \
         at equal offered load -> {:.2}x {}",
        p99[1] * 1e6,
        p99[0] * 1e6,
        p99[0] / p99[1],
        if p99[1] < p99[0] { "(p2c wins the tail)" } else { "(REGRESSION)" }
    );

    // --- Overload sweep: admission control at 2x saturation ---------------
    // Measure a 2-shard flat pool's firehose capacity, then offer twice
    // that as Poisson load under each overload policy. The headline check
    // (ISSUE 4): with a finite queue cap, shed-new / shed-oldest hold the
    // *admitted*-job p99 under the queue's drain bound while sheds > 0,
    // where the unbounded default buffers without limit and lets the tail
    // grow with the run length.
    const OVERLOAD_SHARDS: usize = 2;
    const QUEUE_CAP: usize = 64;
    let overload_wait = Duration::from_micros(500);
    let capacity2 = {
        let fo = forest.clone();
        let server = Server::start_pool_dispatch(
            move |_shard| Ok(FlatExecutor { forest: fo.clone(), max_batch: MAX_BATCH }),
            BatchPolicy { max_batch: MAX_BATCH, max_wait: overload_wait, ..BatchPolicy::default() },
            OVERLOAD_SHARDS,
            DispatchPolicy::P2c,
        )?;
        let cap = firehose_run(&server, &btest, n_requests.min(8_000))?.throughput;
        server.shutdown();
        cap
    };
    let offered = 2.0 * capacity2;
    // Worst admitted wait: a full queue (cap rows) plus up to two in-flight
    // batches drain at the per-shard rate, plus the batching budget.
    let drain_bound = overload_wait.as_secs_f64()
        + (QUEUE_CAP + 2 * MAX_BATCH) as f64 / (capacity2 / OVERLOAD_SHARDS as f64);
    println!(
        "\n== overload sweep: {OVERLOAD_SHARDS}-shard flat capacity {capacity2:.0} rows/s, \
         Poisson @ {offered:.0} rps (2x), queue-cap {QUEUE_CAP}, \
         admitted-p99 bound {:.0}us ==",
        drain_bound * 1e6
    );
    let mut t = Table::new(&[
        "policy", "served/s", "served", "sheds", "queue_full", "p50", "p99", "p99<=bound",
    ]);
    let mut bounded_ok = true;
    let mut unbounded_p99 = 0.0f64;
    let mut shed_p99 = [0.0f64; 2];
    for (i, (label, cap, overload)) in [
        ("unbounded", usize::MAX, OverloadPolicy::Block),
        ("block", QUEUE_CAP, OverloadPolicy::Block),
        ("shed-new", QUEUE_CAP, OverloadPolicy::ShedNew),
        ("shed-oldest", QUEUE_CAP, OverloadPolicy::ShedOldest),
    ]
    .into_iter()
    .enumerate()
    {
        let fo = forest.clone();
        let server = Server::start_pool_dispatch(
            move |_shard| Ok(FlatExecutor { forest: fo.clone(), max_batch: MAX_BATCH }),
            BatchPolicy { max_batch: MAX_BATCH, max_wait: overload_wait, queue_cap: cap, overload },
            OVERLOAD_SHARDS,
            DispatchPolicy::P2c,
        )?;
        let rep = poisson_run_admitting(&server, &btest, n_requests.min(4_000), offered)?;
        let within = rep.latency.p99 <= drain_bound;
        match i {
            0 => unbounded_p99 = rep.latency.p99,
            2 | 3 => {
                shed_p99[i - 2] = rep.latency.p99;
                bounded_ok &= within && rep.sheds > 0;
            }
            _ => {}
        }
        t.row(&[
            label.into(),
            format!("{:.0}", rep.throughput),
            rep.latency.count.to_string(),
            rep.sheds.to_string(),
            rep.queue_full.to_string(),
            format!("{:.0}us", rep.latency.p50 * 1e6),
            format!("{:.0}us", rep.latency.p99 * 1e6),
            if within { "yes" } else { "NO" }.into(),
        ]);
        server.shutdown();
    }
    println!("{}", t.render());
    println!(
        "headline: at 2x saturation, shed-new p99 {:.0}us / shed-oldest p99 {:.0}us vs \
         unbounded p99 {:.0}us; bound {:.0}us -> {}",
        shed_p99[0] * 1e6,
        shed_p99[1] * 1e6,
        unbounded_p99 * 1e6,
        drain_bound * 1e6,
        if bounded_ok {
            "(admission control holds the admitted tail)"
        } else {
            "(REGRESSION: shed policy exceeded the drain bound or shed nothing)"
        }
    );

    // --- Netlist executor sweep: the hardware-accurate path ---------------
    // Serve the *mapped circuit* itself: quantized rows packed 64 per
    // machine word through the bit-parallel gate-level simulator, vs the
    // flat forest at equal load. The table reports the circuit structure
    // and how much of the 64-lane word real traffic filled.
    let netlist_requests = n_requests.min(4_000);
    let compiled = CompiledNetlist::compile(&quant, dp.pipeline)?;
    let compiled_naive =
        CompiledNetlist::compile_with(&quant, dp.pipeline, false, BuildOpts::default())?;
    let meta = compiled.meta();
    println!(
        "\n== netlist executor sweep: {} LUTs, {} FFs, {} cuts, depth {} \
         ({} gates, {} keys; optimizer removed {} gates / {} LUTs vs naive) ==",
        meta.luts,
        meta.ffs,
        meta.cuts,
        meta.levels,
        meta.gates,
        meta.keys,
        meta.gates_saved(),
        meta.luts_saved()
    );
    let mut t = Table::new(&["executor", "shards", "rows/s", "batch", "p50", "p99", "lanes"]);
    let mut flat_equal_load = 0.0f64;
    let mut netlist_rate = 0.0f64;
    let mut netlist_naive_rate = 0.0f64;
    let mut netlist_util = 0.0f64;
    for &shards in &[1usize, 4] {
        for kind in ["flat", "netlist", "netlist-naive"] {
            let policy = BatchPolicy {
                max_batch: MAX_BATCH,
                max_wait: Duration::from_micros(500),
                ..BatchPolicy::default()
            };
            let lanes = Arc::new(LaneStats::default());
            let server = if kind == "flat" {
                let fo = forest.clone();
                Server::start_pool_dispatch(
                    move |_shard| Ok(FlatExecutor { forest: fo.clone(), max_batch: MAX_BATCH }),
                    policy,
                    shards,
                    DispatchPolicy::P2c,
                )?
            } else {
                // "netlist" serves the optimized circuit; "netlist-naive"
                // the pre-rebuild one — same traffic, so the rows/s gap is
                // the serving payoff of the eliminated gates.
                let cn = if kind == "netlist" { compiled.clone() } else { compiled_naive.clone() };
                let lf = Arc::clone(&lanes);
                Server::start_pool_dispatch(
                    move |_shard| Ok(cn.executor(MAX_BATCH, Arc::clone(&lf))),
                    policy,
                    shards,
                    DispatchPolicy::P2c,
                )?
            };
            let cap = firehose_run(&server, &btest, netlist_requests)?;
            let lat = poisson_run(&server, &btest, netlist_requests.min(2_000), rps)?;
            let util = lanes.utilization();
            if shards == 4 {
                match kind {
                    "flat" => flat_equal_load = cap.throughput,
                    "netlist" => {
                        netlist_rate = cap.throughput;
                        netlist_util = util;
                    }
                    _ => netlist_naive_rate = cap.throughput,
                }
            }
            t.row(&[
                kind.into(),
                shards.to_string(),
                format!("{:.0}", cap.throughput),
                format!("{:.1}", cap.mean_batch),
                format!("{:.0}us", lat.latency.p50 * 1e6),
                format!("{:.0}us", lat.latency.p99 * 1e6),
                if kind == "flat" { "-".into() } else { format!("{:.0}%", util * 100.0) },
            ]);
            server.shutdown();
        }
    }
    println!("{}", t.render());
    println!(
        "headline: netlist executor {netlist_rate:.0} rows/s vs flat {flat_equal_load:.0} \
         rows/s at equal load (4 shards) -> {:.3}x; optimized vs naive netlist -> {:.3}x \
         ({netlist_naive_rate:.0} rows/s naive); lanes utilization {:.0}% \
         (rows mod 64 padding waste {:.0}%)",
        netlist_rate / flat_equal_load,
        netlist_rate / netlist_naive_rate,
        netlist_util * 100.0,
        (1.0 - netlist_util) * 100.0
    );

    // --- Lane-coalescing sweep: cross-batch word packing ------------------
    // Small batches (max_batch 8) leave the per-batch path's 64-lane words
    // ~7/8 empty: each batch becomes its own padded word. The coalescing
    // drain instead packs jobs across batch boundaries into full words and
    // streams them through the register-cut pipeline back-to-back (II = 1),
    // so the same traffic fills the lanes.
    let coalesce_requests = n_requests.min(4_000);
    let small = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_micros(500),
        ..BatchPolicy::default()
    };
    println!("\n== lane-coalescing sweep: netlist executor, 8-row batches, 1 shard ==");
    let mut t = Table::new(&["mode", "rows/s", "p50", "p99", "lanes", "words", "flushes", "peak"]);
    let mut coalesce_util = [0.0f64; 2];
    for (i, coalesce) in [false, true].into_iter().enumerate() {
        let lanes = Arc::new(LaneStats::default());
        let cn = compiled.clone();
        let lf = Arc::clone(&lanes);
        let server = if coalesce {
            Server::start_pool_lanes(
                move |_shard| Ok(cn.executor(MAX_BATCH, Arc::clone(&lf))),
                small,
                1,
                DispatchPolicy::P2c,
            )?
        } else {
            Server::start_pool_dispatch(
                move |_shard| Ok(cn.executor(MAX_BATCH, Arc::clone(&lf))),
                small,
                1,
                DispatchPolicy::P2c,
            )?
        };
        let rep = poisson_run(&server, &btest, coalesce_requests, rps)?;
        let s = server.stats();
        coalesce_util[i] = lanes.utilization();
        t.row(&[
            if coalesce { "coalesce" } else { "per-batch" }.into(),
            format!("{:.0}", rep.throughput),
            format!("{:.0}us", rep.latency.p50 * 1e6),
            format!("{:.0}us", rep.latency.p99 * 1e6),
            format!("{:.0}%", coalesce_util[i] * 100.0),
            s.coalesced_words.load(Ordering::Relaxed).to_string(),
            s.pipeline_flushes.load(Ordering::Relaxed).to_string(),
            s.peak_inflight_words.load(Ordering::Relaxed).to_string(),
        ]);
        server.shutdown();
    }
    println!("{}", t.render());
    println!(
        "headline: coalescing fills {:.0}% of the {LANES} lanes vs {:.0}% per-batch \
         under 8-row batches",
        coalesce_util[1] * 100.0,
        coalesce_util[0] * 100.0
    );

    // --- Multi-model registry sweep: two tenants behind one pool ----------
    // The registry tags every row with its tenant and re-groups per batch;
    // this sweep measures what that costs against a single-model pool at
    // the same policy, then hot-swaps tenant 0 under live load through the
    // equivalence gate (which itself samples the model before installing).
    let registry_requests = n_requests.min(8_000);
    {
        let single = {
            let fo = forest.clone();
            let server = Server::start_pool_dispatch(
                move |_shard| Ok(FlatExecutor { forest: fo.clone(), max_batch: MAX_BATCH }),
                BatchPolicy {
                    max_batch: MAX_BATCH,
                    max_wait: Duration::from_micros(500),
                    ..BatchPolicy::default()
                },
                2,
                DispatchPolicy::P2c,
            )?;
            let cap = firehose_run(&server, &btest, registry_requests)?.throughput;
            server.shutdown();
            cap
        };
        let reg = Arc::new(ModelRegistry::new());
        reg.register("mnist-a", ModelArtifact::Flat(Arc::new(forest.clone())))?;
        reg.register("mnist-b", ModelArtifact::Flat(Arc::new(forest.clone())))?;
        let srv = RegistryServer::start(
            Arc::clone(&reg),
            BatchPolicy {
                max_batch: MAX_BATCH,
                max_wait: Duration::from_micros(500),
                ..BatchPolicy::default()
            },
            2,
            DispatchPolicy::P2c,
        )?;
        let before = snapshot(srv.server());
        let t0 = Timer::start();
        let mut pending = Vec::with_capacity(registry_requests);
        for i in 0..registry_requests {
            pending.push(srv.submit(i % 2, btest.row(i % btest.n_rows))?);
        }
        // Swap tenant 0 while the backlog drains: a fresh compile of the
        // same model must clear the gate without disturbing its sibling.
        let swap_t = Timer::start();
        let v = srv.swap(
            0,
            ModelArtifact::Flat(Arc::new(FlatForest::compile(&quant)?)),
            SwapCheck::Equiv,
        )?;
        let swap_secs = swap_t.secs();
        let mut lats = Vec::with_capacity(registry_requests);
        for rx in pending {
            lats.push(rx.recv()??.latency.as_secs_f64());
        }
        let rep =
            ServingReport::from_latencies(&lats, t0.secs(), mean_batch_since(srv.server(), &before), None)
                .with_shards(2)
                .with_models(reg.model_lines());
        println!(
            "\n== registry sweep: 2 tenants, 2 shards, firehose + equiv-gated swap under load =="
        );
        println!("{}", rep.render());
        println!(
            "headline: registry {:.0} rows/s vs single-model pool {single:.0} rows/s at equal \
             policy -> {:.2}x tagging+grouping overhead; swap to v{v} cleared the equivalence \
             gate in {:.1}ms under live load",
            rep.throughput,
            single / rep.throughput.max(1.0),
            swap_secs * 1e3
        );
        srv.shutdown();
    }

    // --- Elastic resize sweep: capacity tracks the shard count ------------
    // One pool, resized live: firehose capacity at 1 shard, after growing
    // to 4 (fresh queues join the dispatch rotation), and after shrinking
    // back to 1 (retired queues drain + redispatch their stragglers).
    {
        let fo = forest.clone();
        let server = Server::start_pool_dispatch(
            move |_shard| Ok(FlatExecutor { forest: fo.clone(), max_batch: MAX_BATCH }),
            BatchPolicy {
                max_batch: MAX_BATCH,
                max_wait: Duration::from_micros(500),
                ..BatchPolicy::default()
            },
            1,
            DispatchPolicy::P2c,
        )?;
        let resize_requests = n_requests.min(8_000);
        let mut t = Table::new(&["shards", "rows/s", "batch", "p50", "p99", "redispatched"]);
        let mut caps = Vec::new();
        for &shards in &[1usize, 4, 1] {
            server.resize(shards)?;
            let rep = firehose_run(&server, &btest, resize_requests)?;
            caps.push(rep.throughput);
            t.row(&[
                shards.to_string(),
                format!("{:.0}", rep.throughput),
                format!("{:.1}", rep.mean_batch),
                format!("{:.0}us", rep.latency.p50 * 1e6),
                format!("{:.0}us", rep.latency.p99 * 1e6),
                server.stats().redispatched.load(Ordering::Relaxed).to_string(),
            ]);
        }
        server.shutdown();
        println!("\n== elastic resize sweep: one pool, live 1 -> 4 -> 1 shards, firehose ==");
        println!("{}", t.render());
        println!(
            "headline: grow 1->4 scaled capacity {:.2}x ({:.0} -> {:.0} rows/s); shrink back \
             returned to {:.0} rows/s on the same pool",
            caps[1] / caps[0].max(1.0),
            caps[0],
            caps[1],
            caps[2]
        );
    }

    // --- PJRT engine section (artifact-gated) -----------------------------
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.txt").exists() {
        println!("\nSKIP PJRT section: artifacts/ missing (run `make artifacts`)");
        return Ok(());
    }
    pjrt_section(&artifacts, n_requests.min(3_000))
}

/// The original PJRT serving sweep over the `jsc` artifact.
fn pjrt_section(artifacts: &std::path::Path, n_requests: usize) -> anyhow::Result<()> {
    let manifest = Manifest::load(artifacts)?;
    let cfg = manifest.get("jsc")?.clone();

    let dp = design_point("jsc", "II").unwrap();
    let ds = synth::jsc_like(10_000, 7);
    let (train_ds, test_ds) = ds.split(0.2, 1);
    let fq = FeatureQuantizer::fit(&train_ds, dp.w_feature);
    let btrain = fq.transform(&train_ds);
    let model = train(&btrain, &train_ds.y, train_ds.n_classes, &dp.params, dp.w_feature)?;
    let (quant, _): (QuantModel, _) = quantize_leaves(&model, dp.w_tree);
    let btest = fq.transform(&test_ds);

    // Raw engine execute rate (no coordinator).
    {
        let tensors = ModelTensors::from_quant(&quant, &cfg)?;
        let engine = match Engine::load(artifacts, &cfg, tensors) {
            Ok(e) => e,
            Err(e) if treelut::runtime::pjrt_unavailable(&e) => {
                println!("\nSKIP PJRT section: {e:#}");
                return Ok(());
            }
            Err(e) => return Err(e),
        };
        let rows: Vec<&[u16]> = (0..cfg.batch).map(|i| btest.row(i)).collect();
        let iters = 200;
        let samples = treelut::util::timer::bench_loop(iters, || engine.predict(&rows).unwrap());
        let s = Summary::of(&samples);
        println!(
            "\nraw engine (PJRT, batch={}): {:.0} exec/s -> {:.0} rows/s (p50 {:.0}us/batch)",
            cfg.batch,
            1.0 / s.p50,
            cfg.batch as f64 / s.p50,
            s.p50 * 1e6
        );
    }

    // Coordinator sweep over the PJRT engine: offered load x max_wait.
    println!("\n== coordinator sweep (PJRT engine, Poisson open-loop) ==");
    let mut t = Table::new(&["rps", "max_wait", "throughput", "batch", "p50", "p99"]);
    for rps in [1_000.0, 4_000.0, 16_000.0] {
        for wait_us in [100u64, 500, 2_000] {
            let (q2, c2, a2) = (quant.clone(), cfg.clone(), artifacts.to_path_buf());
            let server = Server::start_with(
                move || {
                    let tensors = ModelTensors::from_quant(&q2, &c2)?;
                    Engine::load(&a2, &c2, tensors)
                },
                BatchPolicy {
                    max_batch: cfg.batch,
                    max_wait: Duration::from_micros(wait_us),
                    ..BatchPolicy::default()
                },
            )?;
            let rep = poisson_run(&server, &btest, n_requests, rps)?;
            t.row(&[
                format!("{rps:.0}"),
                format!("{wait_us}us"),
                format!("{:.0}/s", rep.throughput),
                format!("{:.1}", rep.mean_batch),
                format!("{:.0}us", rep.latency.p50 * 1e6),
                format!("{:.0}us", rep.latency.p99 * 1e6),
            ]);
            server.shutdown();
        }
    }
    println!("{}", t.render());
    Ok(())
}
