//! Tool-flow wall-clock benchmark (paper §4.2: "the TreeLUT tool took a few
//! seconds to quantize a given XGBoost model, test it for accuracy, and
//! convert it into RTL code" — vs hours for some LUT-based NN tools).
//!
//! Also benchmarks the substrate hot paths (histogram training, LUT
//! mapping, bit-parallel gate simulation) for the EXPERIMENTS.md perf
//! section.
//!
//! Run: `cargo bench --bench toolflow_time [-- --rows N]`

use treelut::exp::configs::{default_rows, design_points};
use treelut::exp::table::Table;
use treelut::exp::{run_design_point, RunOptions};
use treelut::netlist::conform::fixtures;
use treelut::netlist::{
    build_netlist, check_equiv, map_luts, optimize_built, verify_built, Simulator,
};
use treelut::quantize::quantize_leaves;
use treelut::rtl::{design_from_quant, verilog::emit_verilog};
use treelut::util::{Args, Timer};

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let rows_override = args.opt("rows").map(|r| r.parse::<usize>().unwrap());
    args.finish()?;

    let mut t = Table::new(&[
        "design point", "train(s)", "quantize+IR(s)", "netlist+map(s)", "opt(s)", "equiv(s)",
        "verify(s)", "verilog(s)", "sim rate (Msample-gate/s)", "gates pre>post",
        "LUTs pre>post",
    ]);
    for dp in design_points() {
        let rows =
            rows_override.unwrap_or_else(|| default_rows(dp.dataset));
        let r = run_design_point(
            &dp,
            &RunOptions { rows, seed: 7, bypass_keygen: false, simulate: false },
        )?;
        let design = design_from_quant("t", &r.quant, dp.pipeline, true);

        let tm = Timer::start();
        let verilog = emit_verilog(&design);
        let t_verilog = tm.secs();
        std::hint::black_box(verilog.len());

        // Gate-sim throughput: one 64-lane batch over the whole netlist.
        let built = build_netlist(&design);
        let map = map_luts(&built.net);

        // Hash-consed optimizing rebuild + the equivalence gate over it.
        let tm = Timer::start();
        let opt = optimize_built(&built);
        let t_opt = tm.secs();
        let map_opt = map_luts(&opt.net);
        let tm = Timer::start();
        let eq = check_equiv(&built, &opt)?;
        let t_equiv = tm.secs();
        anyhow::ensure!(eq.equivalent(), "{} {}: optimizer broke the circuit", dp.dataset, dp.label);

        // Static verifier wall time (all four passes over the mapped design).
        let tm = Timer::start();
        let report = verify_built(&built, Some(&map));
        let t_verify = tm.secs();
        std::hint::black_box(report.diagnostics.len());

        let mut sim = Simulator::new(&built.net);
        let mut batch = treelut::netlist::simulate::InputBatch::new(built.net.n_inputs);
        for i in 0..64u16 {
            let row: Vec<u16> = (0..design.n_features)
                .map(|f| ((i as usize + f) % (1 << design.w_feature)) as u16)
                .collect();
            batch.push_features(&row, design.w_feature as usize).unwrap();
        }
        let iters = 20;
        let samples = treelut::util::timer::bench_loop(iters, || sim.run(&built.net, &batch));
        let per_batch = treelut::util::Summary::of(&samples).p50;
        let rate = 64.0 * built.net.len() as f64 / per_batch / 1e6;

        t.row(&[
            format!("{} {}", dp.dataset, dp.label),
            format!("{:.2}", r.t_train),
            format!("{:.3}", r.t_quantize),
            format!("{:.3}", r.t_map),
            format!("{t_opt:.3}"),
            format!("{t_equiv:.3}"),
            format!("{t_verify:.3}"),
            format!("{t_verilog:.3}"),
            format!("{rate:.0}"),
            format!("{}>{}", built.net.len(), opt.net.len()),
            format!("{}>{}", map.luts, map_opt.luts),
        ]);
    }
    println!("== tool-flow wall clock (paper 4.2: 'a few seconds') ==");
    println!("{}", t.render());

    // Verifier wall time over the frozen conformance fixtures — the same
    // netlists the CI lint job checks, so this tracks lint latency.
    let mut v = Table::new(&[
        "fixture", "gates pre>post", "LUTs pre>post", "diags", "verify(s)", "equiv(s)",
    ]);
    for fixture in fixtures() {
        let (quant, _) = quantize_leaves(&fixture.model, fixture.w_tree);
        let design = design_from_quant(fixture.name, &quant, fixture.pipeline, true);
        let built = build_netlist(&design);
        let map = map_luts(&built.net);
        let tm = Timer::start();
        let report = verify_built(&built, Some(&map));
        let t_verify = tm.secs();
        let opt = optimize_built(&built);
        let map_opt = map_luts(&opt.net);
        let tm = Timer::start();
        let eq = check_equiv(&built, &opt)?;
        let t_equiv = tm.secs();
        anyhow::ensure!(eq.equivalent(), "{}: optimizer broke the fixture", fixture.name);
        v.row(&[
            fixture.name.to_string(),
            format!("{}>{}", built.net.len(), opt.net.len()),
            format!("{}>{}", map.luts, map_opt.luts),
            report.diagnostics.len().to_string(),
            format!("{t_verify:.4}"),
            format!("{t_equiv:.4}"),
        ]);
    }
    println!();
    println!("== static verifier wall clock (conformance fixtures) ==");
    println!("{}", v.render());
    Ok(())
}
