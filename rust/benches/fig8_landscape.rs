//! Regenerates paper **Fig. 8**: the accuracy vs area-delay landscape
//! (log-scale AxD bars + accuracy line, per dataset).
//!
//! Emits the plot data as aligned text + CSV so the figure regenerates with
//! any plotting tool. TreeLUT points are substrate-measured; prior works
//! are quoted (as in the paper).
//!
//! Run: `cargo bench --bench fig8_landscape [-- --rows N --csv out.csv]`

use treelut::exp::prior::TABLE5;
use treelut::exp::table::{pct, sci, Table};
use treelut::exp::{design_points, run_design_point, RunOptions};
use treelut::util::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let rows_override = args.opt("rows").map(|r| r.parse::<usize>().unwrap());
    let csv_path = args.opt("csv");
    args.finish()?;

    let mut series: Vec<(String, String, f64, f64, &'static str)> = Vec::new(); // dataset, method, axd, acc, src
    for dp in design_points() {
        let rows =
            rows_override.unwrap_or_else(|| treelut::exp::configs::default_rows(dp.dataset));
        let r = run_design_point(
            &dp,
            &RunOptions { rows, seed: 7, bypass_keygen: false, simulate: false },
        )?;
        series.push((
            dp.dataset.to_string(),
            dp.label.to_string(),
            r.cost.area_delay,
            r.acc_quant,
            "measured",
        ));
    }
    for p in TABLE5 {
        series.push((
            p.dataset.to_string(),
            p.method.to_string(),
            p.area_delay(),
            p.accuracy,
            "quoted",
        ));
    }

    for dataset in ["mnist", "jsc", "nid"] {
        println!("== Fig. 8 [{dataset}]: Area-Delay (log scale) and Accuracy ==");
        let mut points: Vec<_> = series.iter().filter(|s| s.0 == dataset).collect();
        points.sort_by(|a, b| a.2.partial_cmp(&b.2).unwrap());
        let mut t = Table::new(&["Method", "AxD", "log10(AxD) bar", "Accuracy", "source"]);
        for (_, method, axd, acc, src) in points {
            let log = axd.log10();
            let bar = "#".repeat((log * 4.0).round().max(1.0) as usize);
            t.row(&[method.clone(), sci(*axd), bar, pct(*acc), src.to_string()]);
        }
        println!("{}", t.render());
    }

    if let Some(path) = csv_path {
        let mut csv = String::from("dataset,method,area_delay,accuracy,source\n");
        for (d, m, axd, acc, src) in &series {
            csv.push_str(&format!("{d},{m},{axd},{acc},{src}\n"));
        }
        std::fs::write(&path, csv)?;
        println!("wrote {path}");
    }
    Ok(())
}
