//! Ablation of the paper's quantization choices (§2.2):
//!
//! 1. `w_feature × w_tree` sweep on JSC — accuracy vs hardware cost (the
//!    trade-off Table 2's grid search navigates);
//! 2. TreeLUT local-shift quantization vs the Conifer-style post-training
//!    fixed-point baseline at matched operand widths (the §1/§4.3 claim
//!    that PTQ loses accuracy at low bits and needs wider datapaths).
//!
//! Run: `cargo bench --bench ablation_quantization [-- --rows N]`

use treelut::baselines::quantize_leaves_conifer;
use treelut::data::{accuracy, synth};
use treelut::exp::table::{pct, Table};
use treelut::gbdt::{train, BoostParams};
use treelut::netlist::{build_netlist, map_luts, CostReport, TimingModel};
use treelut::quantize::{quantize_leaves, FeatureQuantizer};
use treelut::rtl::{design_from_quant, Pipeline};
use treelut::util::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let rows = args.get_as::<usize>("rows", 20_000);
    args.finish()?;

    let ds = synth::jsc_like(rows, 7);
    let (train_ds, test_ds) = ds.split(0.2, 1);

    // --- Sweep 1: w_feature × w_tree -------------------------------------
    println!("== quantization sweep [jsc]: w_feature x w_tree ==");
    let mut t = Table::new(&[
        "w_feature", "w_tree", "acc(float)", "acc(quant)", "LUT", "Fmax", "AxD",
    ]);
    for w_feature in [2u8, 4, 8] {
        let fq = FeatureQuantizer::fit(&train_ds, w_feature);
        let btrain = fq.transform(&train_ds);
        let btest = fq.transform(&test_ds);
        let params = BoostParams::default().n_estimators(13).max_depth(5).eta(0.8);
        let model = train(&btrain, &train_ds.y, train_ds.n_classes, &params, w_feature)?;
        let acc_float =
            accuracy(&model.predict_batch(&btest.bins, btest.n_features), &test_ds.y);
        for w_tree in [1u8, 2, 3, 4, 6] {
            let (qm, _) = quantize_leaves(&model, w_tree);
            let acc_q = accuracy(&qm.predict_batch(&btest.bins, btest.n_features), &test_ds.y);
            let design = design_from_quant("q", &qm, Pipeline::new(0, 1, 1), true);
            let built = build_netlist(&design);
            let map = map_luts(&built.net);
            let cost = CostReport::evaluate(&map, built.cuts, &TimingModel::default());
            t.row(&[
                w_feature.to_string(),
                w_tree.to_string(),
                pct(acc_float),
                pct(acc_q),
                cost.luts.to_string(),
                format!("{:.0}", cost.fmax_mhz),
                format!("{:.2e}", cost.area_delay),
            ]);
        }
    }
    println!("{}", t.render());

    // --- Sweep 2: TreeLUT vs Conifer-style PTQ ----------------------------
    println!("== TreeLUT local-shift vs Conifer-style PTQ (matched operand bits) ==");
    let fq = FeatureQuantizer::fit(&train_ds, 8);
    let btrain = fq.transform(&train_ds);
    let btest = fq.transform(&test_ds);
    let params = BoostParams::default().n_estimators(13).max_depth(5).eta(0.8);
    let model = train(&btrain, &train_ds.y, train_ds.n_classes, &params, 8)?;
    let mut t2 = Table::new(&[
        "operand bits", "TreeLUT acc", "Conifer acc", "TreeLUT LUT", "Conifer LUT",
        "TreeLUT AxD", "Conifer AxD",
    ]);
    for bits in [2u8, 3, 4, 5, 6] {
        let (tl, _) = quantize_leaves(&model, bits);
        let cf = quantize_leaves_conifer(&model, bits + 1, bits.saturating_sub(1));
        let acc_tl = accuracy(&tl.predict_batch(&btest.bins, btest.n_features), &test_ds.y);
        let acc_cf = accuracy(&cf.predict_batch(&btest.bins, btest.n_features), &test_ds.y);
        let cost = |qm: &treelut::quantize::QuantModel| {
            let d = design_from_quant("c", qm, Pipeline::new(0, 1, 1), true);
            let b = build_netlist(&d);
            let m = map_luts(&b.net);
            CostReport::evaluate(&m, b.cuts, &TimingModel::default())
        };
        let (c_tl, c_cf) = (cost(&tl), cost(&cf));
        t2.row(&[
            bits.to_string(),
            pct(acc_tl),
            pct(acc_cf),
            c_tl.luts.to_string(),
            c_cf.luts.to_string(),
            format!("{:.2e}", c_tl.area_delay),
            format!("{:.2e}", c_cf.area_delay),
        ]);
    }
    println!("{}", t2.render());
    println!("expected shape: Conifer's signed offset leaves widen every tree output,");
    println!("so its LUT/AxD exceeds TreeLUT at every operand width, and its accuracy");
    println!("degrades faster at low bitwidths (paper 2.2.2 and the 4.3 discussion).");
    Ok(())
}
