//! Regenerates paper **Table 6**: the apples-to-apples comparison with DWN.
//!
//! DWN binarizes inputs offline (distributive thermometer encoding), so the
//! paper bypasses TreeLUT's key-generator layer for this comparison — the
//! circuit takes precomputed key bits as inputs. We measure TreeLUT (I)
//! with `bypass_keygen`, and quote DWN's published numbers.
//!
//! Run: `cargo bench --bench table6_dwn [-- --rows N]`

use treelut::exp::configs::{default_rows, design_point};
use treelut::exp::prior::TABLE6_DWN;
use treelut::exp::table::{pct, sci, Table};
use treelut::exp::{run_design_point, RunOptions};
use treelut::util::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let rows_override = args.opt("rows").map(|r| r.parse::<usize>().unwrap());
    args.finish()?;

    println!("== Table 6: TreeLUT (key generator bypassed) vs DWN ==\n");
    let mut t = Table::new(&[
        "Dataset", "Method", "Model", "Acc", "LUT", "FF", "Fmax(MHz)", "Lat(ns)", "AxD",
        "AxD ratio", "source",
    ]);
    for dataset in ["mnist", "jsc"] {
        let dp = design_point(dataset, "I").unwrap();
        let rows = rows_override.unwrap_or_else(|| default_rows(dataset));
        let r = run_design_point(
            &dp,
            &RunOptions { rows, seed: 7, bypass_keygen: true, simulate: false },
        )?;
        let dwn = TABLE6_DWN.iter().find(|p| p.dataset == dataset).unwrap();
        let base = r.cost.area_delay;
        t.row(&[
            dataset.into(),
            "TreeLUT".into(),
            "DT".into(),
            pct(r.acc_quant),
            r.cost.luts.to_string(),
            r.cost.ffs.to_string(),
            format!("{:.0}", r.cost.fmax_mhz),
            format!("{:.1}", r.cost.latency_ns),
            sci(base),
            "1.00".into(),
            "measured".into(),
        ]);
        t.row(&[
            dataset.into(),
            "DWN".into(),
            "NN".into(),
            pct(dwn.accuracy),
            dwn.luts.to_string(),
            dwn.ffs.map(|f| f.to_string()).unwrap_or_default(),
            format!("{:.0}", dwn.fmax_mhz),
            format!("{:.1}", dwn.latency_ns),
            sci(dwn.area_delay()),
            format!("{:.2}", dwn.area_delay() / base),
            "quoted".into(),
        ]);
        println!(
            "shape check [{dataset}]: DWN/TreeLUT AxD ratio = {:.1}x (paper: {})",
            dwn.area_delay() / base,
            if dataset == "mnist" { "4.0x" } else { "7.6x" }
        );
    }
    println!("\n{}", t.render());
    Ok(())
}
