//! Regenerates paper **Table 5**: hardware cost comparison of TreeLUT
//! against prior works on MNIST / JSC / NID.
//!
//! TreeLUT rows are measured through the netlist substrate (LUT mapping +
//! calibrated timing model, DESIGN.md §7); prior-work rows are quoted from
//! their original papers, exactly as the paper itself quotes them. The
//! Area×Delay Ratio column is normalized to the best TreeLUT (II) row per
//! dataset, like the paper.
//!
//! Run: `cargo bench --bench table5_hardware [-- --rows N]`

use treelut::exp::prior::{TABLE5, TABLE5_TREELUT_PAPER};
use treelut::exp::table::{pct, sci, Table};
use treelut::exp::{design_points, run_design_point, PointResult, RunOptions};
use treelut::util::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let rows_override = args.opt("rows").map(|r| r.parse::<usize>().unwrap());
    args.finish()?;

    // Measure the six TreeLUT design points.
    let mut measured: Vec<PointResult> = Vec::new();
    for dp in design_points() {
        let rows =
            rows_override.unwrap_or_else(|| treelut::exp::configs::default_rows(dp.dataset));
        measured.push(run_design_point(
            &dp,
            &RunOptions { rows, seed: 7, bypass_keygen: false, simulate: false },
        )?);
    }

    for dataset in ["mnist", "jsc", "nid"] {
        println!("== Table 5 [{dataset}] ==");
        let base_ad = measured
            .iter()
            .filter(|m| m.dataset == dataset)
            .map(|m| m.cost.area_delay)
            .fold(f64::INFINITY, f64::min);
        let mut t = Table::new(&[
            "Method", "Model", "Acc", "LUT", "FF", "DSP", "BRAM", "Fmax(MHz)", "Lat(ns)",
            "AxD", "AxD ratio", "source",
        ]);
        for m in measured.iter().filter(|m| m.dataset == dataset) {
            t.row(&[
                m.label.clone(),
                "DT".into(),
                pct(m.acc_quant),
                m.cost.luts.to_string(),
                m.cost.ffs.to_string(),
                "0".into(),
                "0".into(),
                format!("{:.0}", m.cost.fmax_mhz),
                format!("{:.1}", m.cost.latency_ns),
                sci(m.cost.area_delay),
                format!("{:.2}", m.cost.area_delay / base_ad),
                "measured".into(),
            ]);
        }
        for p in TABLE5_TREELUT_PAPER.iter().filter(|p| p.dataset == dataset) {
            t.row(&[
                p.method.into(),
                p.model.into(),
                pct(p.accuracy),
                p.luts.to_string(),
                p.ffs.map(|f| f.to_string()).unwrap_or_else(|| "-".into()),
                p.dsps.to_string(),
                p.brams.to_string(),
                format!("{:.0}", p.fmax_mhz),
                format!("{:.1}", p.latency_ns),
                sci(p.area_delay()),
                format!("{:.2}", p.area_delay() / base_ad),
                "paper".into(),
            ]);
        }
        for p in TABLE5.iter().filter(|p| p.dataset == dataset) {
            t.row(&[
                p.method.into(),
                p.model.into(),
                pct(p.accuracy),
                p.luts.to_string(),
                p.ffs.map(|f| f.to_string()).unwrap_or_else(|| "-".into()),
                p.dsps.to_string(),
                p.brams.to_string(),
                format!("{:.0}", p.fmax_mhz),
                format!("{:.1}", p.latency_ns),
                sci(p.area_delay()),
                format!("{:.2}", p.area_delay() / base_ad),
                "quoted".into(),
            ]);
        }
        println!("{}", t.render());

        // The paper's headline claim per dataset: TreeLUT wins area-delay
        // at comparable accuracy. Check the *shape* against the best
        // non-TreeLUT prior row.
        let best_prior = TABLE5
            .iter()
            .filter(|p| p.dataset == dataset)
            .map(|p| p.area_delay())
            .fold(f64::INFINITY, f64::min);
        println!(
            "shape check [{dataset}]: best measured TreeLUT AxD {} vs best prior {} -> {}\n",
            sci(base_ad),
            sci(best_prior),
            if base_ad < best_prior { "TreeLUT wins (matches paper)" } else { "MISMATCH" }
        );
    }
    Ok(())
}
